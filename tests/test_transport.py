"""Message-passing transport: wire accounting, cross-object coalescing,
and message-level failure policies (drop / delay / partition).

The stats-parity test pins ``net_bytes``/``lookup_unicasts`` to the values
the pre-transport accounting produced on the same fixed workload (captured
on the PR 1 tree) — the refactor must not change what crosses the wire,
only where it is counted.
"""

import numpy as np
import pytest

from repro.core import (
    CONTROL_MSG_BYTES,
    ChunkOp,
    ChunkOpBatch,
    ChunkRead,
    ChunkingSpec,
    DecrefBatch,
    DedupCluster,
    OmapPut,
    OMAPEntry,
    WriteError,
    delay,
    drop,
    partition,
    reliable,
    sha256_fp,
)

CH = ChunkingSpec("fixed", 1024)
RNG = np.random.default_rng(7)


# --------------------------------------------------------------- wire model
def test_message_wire_bytes_model():
    fp = sha256_fp(b"x" * 100)
    blob = b"y" * 500
    # payload op costs its bytes, except toward its own origin
    batch = ChunkOpBatch((ChunkOp(fp, blob, origin="oss0"),), txn=1)
    assert batch.payload_bytes("oss1") == 500
    assert batch.payload_bytes("oss0") == 0
    assert batch.wire_bytes("oss1") == CONTROL_MSG_BYTES + 500
    assert batch.lookups() == 1
    # ref-only ops never carry bytes
    ref = ChunkOpBatch((ChunkOp(fp, None, origin="oss0"),), txn=1)
    assert ref.payload_bytes("oss1") == 0
    assert ref.lookups() == 1
    # fp-first: bytes only travel for ops that were not dedup hits
    probe = ChunkOpBatch((ChunkOp(fp, blob, origin="oss0"),), txn=1, fp_first=True)
    assert probe.payload_bytes("oss1", ["dedup_hit"]) == 0
    assert probe.payload_bytes("oss1", ["stored"]) == 500
    # OMAP commit records are control-only; migrated entries ship a record
    entry = OMAPEntry("a", fp, [fp], 100)
    assert OmapPut(entry).wire_bytes("oss1") == CONTROL_MSG_BYTES
    assert OmapPut(entry, migrate=True).wire_bytes("oss1") == 2 * CONTROL_MSG_BYTES
    # chunk reads pay for the returned bytes
    assert ChunkRead(fp).wire_bytes("oss1", blob) == CONTROL_MSG_BYTES + 500
    assert DecrefBatch((fp,)).wire_bytes("oss1") == CONTROL_MSG_BYTES


def test_wire_bytes_identity_and_per_edge_stats():
    c = DedupCluster.create(4, chunking=CH)
    data = RNG.bytes(8192)
    c.write_object("a", data)
    assert c.read_object("a") == data
    t = c.transport
    # every delivered message costs one control header on top of payload,
    # and every delivery is acked (ack bytes are part of net_bytes)
    assert t.wire_bytes == t.net_bytes + CONTROL_MSG_BYTES * t.deliveries
    assert t.acks_sent == t.deliveries == t.messages_sent - t.dropped
    assert t.ack_bytes == t.acks_sent * CONTROL_MSG_BYTES
    # the client ingress edge carries the object bytes
    edges = {k: v for k, v in t.edges.items() if k[0] == "client" and v.payload_bytes}
    assert sum(e.payload_bytes for e in edges.values()) >= len(data)
    assert t.msgs_by_type["omap_put"] >= 1
    assert t.msgs_by_type["chunk_op_batch"] >= 1
    # batched restore: one ChunkReadBatch per node holding chunks (the 8
    # chunks land on 3 of the 4 nodes), not one ChunkRead per chunk
    assert t.msgs_by_type["chunk_read_batch"] == 3
    assert "chunk_read" not in t.msgs_by_type
    # the serial per-chunk shape is preserved behind batch_reads=False
    c.batch_reads = False
    assert c.read_object("a") == data
    assert t.msgs_by_type["chunk_read"] == 8  # one per chunk


# ------------------------------------------------------------- stats parity
def test_stats_parity_with_pre_transport_accounting():
    """Fixed no-failure workload (writes, batch write, duplicate, ref-write,
    reads, delete, rebalance, scrub). net_bytes and lookup_unicasts are
    pinned to the pre-refactor values measured on the PR 1 tree;
    control_msgs is pinned to the transport's message count so accidental
    message-shape changes surface here."""
    rng = np.random.default_rng(1234)
    c = DedupCluster.create(5, replicas=2, chunking=CH)
    items = [(f"obj{i}", rng.bytes(3000 + 137 * i)) for i in range(8)]
    for n, d in items[:4]:
        c.write_object(n, d)
    c.write_objects(items[4:])
    c.write_object("dup", items[0][1])
    c.tick(2)
    assert c.write_object_by_ref("ref", "obj1") is not None
    for n, d in items:
        assert c.read_object(n) == d
    c.delete_object("obj3")
    c.add_node()
    c.scrub()
    c.tick(2)
    # payload parity: net_bytes minus the at-least-once ack bytes minus the
    # recovery digest traffic is the pre-refactor exact payload accounting;
    # the ack surcharge is exactly one ACK_MSG_BYTES (=CONTROL_MSG_BYTES)
    # per delivery. Scrub is digest-driven now: one summary DigestRequest
    # per live node (6), whose replies carry 40 per-group digest records
    # (DIGEST_GROUP_BYTES each) — fully replicated cluster, so no group
    # mismatches, no detail listings, no RepairChunk traffic.
    assert c.transport.msgs_by_type["digest_request"] == 6
    assert c.transport.msgs_by_type.get("repair_chunk", 0) == 0
    digest_bytes = 40 * 16
    assert c.stats.net_bytes - c.stats.ack_bytes - digest_bytes == 127200
    assert c.stats.ack_bytes == 64 * c.transport.deliveries
    # PR 9 coalesced the restore path (one ChunkReadBatch per node instead
    # of one ChunkRead per chunk): 10 fewer read messages/acks than the
    # serial shape, while the PAYLOAD parity above is untouched — the same
    # chunk bytes cross the wire, under fewer control headers.
    assert c.stats.net_bytes == 137056        # 127200 + 640 + 64 * 144 deliveries
    assert c.stats.lookup_unicasts == 76      # pre-refactor exact
    assert c.stats.lookup_broadcasts == 0
    assert c.stats.control_msgs == 144        # transport message count (+6 digests)
    assert c.stats.retransmits == 0           # reliable policy: no retries
    assert c.stats.rebalance_bytes_moved == 12079
    assert c.stats.rebalance_chunks_moved == 13
    assert c.unique_bytes_stored() == 27836


def test_coalesced_batch_one_unicast_per_node():
    """32-object batched write: ONE ChunkOpBatch per target node for the
    whole batch (not per object per node), strictly fewer control messages,
    identical bytes on the wire and identical cluster state."""
    rng = np.random.default_rng(42)
    items = [(f"b{i}", rng.bytes(16 * 1024)) for i in range(32)]
    per_obj = DedupCluster.create(8, chunking=CH, coalesce_batches=False)
    coal = DedupCluster.create(8, chunking=CH)
    f1 = per_obj.write_objects(list(items))
    f2 = coal.write_objects(list(items))
    assert f1 == f2
    assert coal.transport.msgs_by_type["chunk_op_batch"] == 8  # == n_nodes
    assert per_obj.transport.msgs_by_type["chunk_op_batch"] > 8 * 16
    assert coal.stats.control_msgs < per_obj.stats.control_msgs
    # PR 1 measured 261 control messages for this workload; the coalesced
    # transport must be strictly below it
    assert coal.stats.control_msgs < 261
    # identical payload bytes; coalescing ALSO saves ack bytes (fewer
    # messages -> fewer acks), so total net_bytes is strictly lower
    payload = lambda c: c.stats.net_bytes - c.stats.ack_bytes  # noqa: E731
    assert payload(coal) == payload(per_obj) == 978944
    assert coal.stats.net_bytes < per_obj.stats.net_bytes
    assert coal.stats.lookup_unicasts == per_obj.stats.lookup_unicasts == 512
    for nid in coal.nodes:
        assert coal.nodes[nid].chunk_store == per_obj.nodes[nid].chunk_store


def test_intra_batch_duplicates_become_ref_only():
    """Chunks repeated across objects in one batch ship their bytes once;
    later objects ride ref-only ops (refcounts still exact)."""
    blob = RNG.bytes(4096)
    items = [(f"dup{i}", blob) for i in range(4)]
    coal = DedupCluster.create(4, chunking=CH)
    per_obj = DedupCluster.create(4, chunking=CH, coalesce_batches=False)
    coal.write_objects(list(items))
    per_obj.write_objects(list(items))
    # per-object: every object's chunk bytes travel (paper-faithful);
    # coalesced: one copy of the payload + 3 ref-only rides
    assert coal.stats.net_bytes < per_obj.stats.net_bytes
    assert coal.stats.lookup_unicasts == per_obj.stats.lookup_unicasts
    for c in (coal, per_obj):
        for node in c.nodes.values():
            for fp, e in node.shard.cit.items():
                assert e.refcount == 4, fp
        for i in range(4):
            assert c.read_object(f"dup{i}") == blob
    assert coal.unique_bytes_stored() == per_obj.unique_bytes_stored() == 4096


# -------------------------------------------------------- failure policies
def test_lost_chunk_op_batch_rollback_and_gc():
    """A dropped ChunkOpBatch fails the write transaction; the rollback
    releases the refs taken on reachable nodes, leaving flag-0 garbage that
    GC collects — the paper's failure model, now reachable from the wire."""
    c = DedupCluster.create(4, chunking=CH)
    victim = "oss2"

    def lose_chunk_batches_to_victim(src, dst, msg, now):
        if isinstance(msg, ChunkOpBatch) and dst == victim:
            return ("drop", 0)
        return ("deliver", 0)

    c.transport.policy = lose_chunk_batches_to_victim
    data = np.random.default_rng(3).bytes(16 * 1024)  # 16 chunks over 4 nodes
    with pytest.raises(WriteError):
        c.write_object("x", data)
    assert c.stats.writes_failed == 1
    assert c.transport.dropped >= 1
    # every ref the txn took was rolled back; stored chunks are tombstones
    garbage = 0
    for node in c.nodes.values():
        for fp, e in node.shard.cit.items():
            assert e.refcount == 0 and e.flag == 0
            garbage += 1
    assert garbage > 0
    # nothing committed
    assert all(not n.shard.omap for n in c.nodes.values())
    # GC collects the flag-0 garbage once it ages out
    c.transport.policy = reliable()
    c.tick(20)
    c.run_gc()
    c.tick(20)
    removed = sum(len(v) for v in c.run_gc().values())
    assert removed == garbage
    assert c.unique_bytes_stored() == 0
    # the retry over a healthy transport succeeds
    c.write_object("x", data)
    assert c.read_object("x") == data


def test_seeded_drop_policy_keeps_invariants():
    """Chaos: every write either commits (readable) or raises (no OMAP
    entry) under a seeded lossy policy."""
    c = DedupCluster.create(4, replicas=2, chunking=CH,
                            policy=drop(0.3, seed=11, only=(ChunkOpBatch,)))
    rng = np.random.default_rng(5)
    written: dict[str, bytes] = {}
    failed = 0
    for i in range(12):
        data = rng.bytes(4096)
        try:
            c.write_object(f"o{i}", data)
            written[f"o{i}"] = data
        except WriteError:
            failed += 1
    assert written and failed, "seeded policy should produce both outcomes"
    c.transport.policy = reliable()
    committed = set()
    for node in c.nodes.values():
        committed.update(node.shard.omap.keys())
    assert committed == set(written)
    for name, data in written.items():
        assert c.read_object(name) == data


def test_delayed_flip_repaired_on_read():
    """A delayed ChunkOpBatch registers its commit-flag flips with the
    shifted receive time, so the flags are still INVALID long after the
    usual async window — the read path's consistency check repairs them
    (paper §2.4 repair-on-read)."""
    c = DedupCluster.create(3, chunking=CH, policy=delay(10, only=(ChunkOpBatch,)))
    data = RNG.bytes(4096)
    c.write_object("x", data)
    c.tick(2)  # would flip every flag on an undelayed write
    invalid = sum(len(n.shard.invalid_fps()) for n in c.nodes.values())
    assert invalid == 4, "flips must still be pending behind the delay"
    assert c.read_object("x") == data
    assert sum(len(n.shard.invalid_fps()) for n in c.nodes.values()) == 0
    assert sum(n.stats.repairs for n in c.nodes.values()) == 4
    # the late flips land on already-repaired entries without harm
    c.tick(15)
    assert c.read_object("x") == data


def test_partition_heals_with_scrub():
    c = DedupCluster.create(4, replicas=2, chunking=CH)
    rng = np.random.default_rng(9)
    base = rng.bytes(8192)
    c.write_object("pre", base)
    c.tick(2)
    c.transport.policy = partition(("oss0", "oss1"), ("oss2", "oss3"))
    # reads still work: the external client reaches every node
    assert c.read_object("pre") == base
    attempts = {}
    committed = {}
    for i in range(8):
        data = rng.bytes(4096)
        attempts[f"w{i}"] = data
        try:
            c.write_object(f"w{i}", data)
            committed[f"w{i}"] = data
        except WriteError:
            pass
    assert c.transport.dropped > 0
    # heal; committed objects read back, failed ones left no OMAP entry
    c.transport.policy = reliable()
    names_on_cluster = set()
    for node in c.nodes.values():
        names_on_cluster.update(node.shard.omap.keys())
    assert names_on_cluster == set(committed) | {"pre"}
    for name, data in committed.items():
        assert c.read_object(name) == data
    # scrub restores full replication for copies lost to the partition
    c.scrub()
    c.tick(2)
    for node in c.nodes.values():
        for fp in node.chunk_store:
            for t in c.chunk_targets(fp):
                assert fp in c.nodes[t].chunk_store


def test_fault_injector_sees_transport_events():
    seen = []

    def inj(event, ctx):
        if event == "transport_send":
            seen.append((ctx["src"], ctx["dst"], ctx["type"]))

    c = DedupCluster.create(3, chunking=CH, fault_injector=inj)
    c.write_object("a", RNG.bytes(2048))
    types = {t for _, _, t in seen}
    assert "chunk_op_batch" in types and "omap_put" in types and "omap_get" in types


def test_coalesced_commit_failure_rolls_back_tail_and_retry_matches_serial():
    """Force coalescing under a fault injector (batch_unicasts=True) and
    abort the third object's commit: objects before it commit, the failed
    object and everything after roll back, and retrying the tail reproduces
    the serial loop's end state exactly."""
    from repro.core import TransactionAbort

    rng = np.random.default_rng(21)
    items = [(f"o{i}", rng.bytes(4096)) for i in range(6)]

    def abort_o2(event, ctx):
        if event == "before_omap" and ctx.get("name") == "o2":
            raise TransactionAbort("injected")

    b = DedupCluster.create(4, chunking=CH, batch_unicasts=True,
                            fault_injector=abort_o2)
    with pytest.raises(WriteError):
        b.write_objects(list(items))
    assert b.stats.writes_ok == 2 and b.stats.writes_failed == 1
    committed = set()
    for node in b.nodes.values():
        committed.update(node.shard.omap.keys())
    assert committed == {"o0", "o1"}
    # the tail (o2..o5) retried without the injector matches a serial run
    b.fault_injector = None
    done = b.stats.writes_ok + b.stats.writes_failed
    b.write_objects(items[done - 1:])

    a = DedupCluster.create(4, chunking=CH)
    for n, d in items:
        a.write_object(n, d)
    for nid in a.nodes:
        assert a.nodes[nid].chunk_store == b.nodes[nid].chunk_store
        cit_a = {fp: (e.refcount, e.size) for fp, e in a.nodes[nid].shard.cit.items()}
        cit_b = {fp: (e.refcount, e.size) for fp, e in b.nodes[nid].shard.cit.items()}
        assert cit_a == cit_b
    assert a.stats.logical_bytes_written + items[2][1].__len__() == \
        b.stats.logical_bytes_written  # o2 was counted twice: failed try + retry
    for n, d in items:
        assert b.read_object(n) == d


def test_coalesced_planning_abort_still_commits_earlier_objects():
    """A TransactionAbort at a planning-phase event (primary_selected) must
    not take down the whole wave: objects planned before it commit, then
    the abort propagates — matching the serial loop."""
    from repro.core import TransactionAbort

    rng = np.random.default_rng(41)
    items = [(f"p{i}", rng.bytes(4096)) for i in range(5)]

    def abort_p3(event, ctx):
        if event == "primary_selected" and ctx.get("name") == "p3":
            raise TransactionAbort("injected at planning")

    c = DedupCluster.create(4, chunking=CH, batch_unicasts=True,
                            fault_injector=abort_p3)
    with pytest.raises(TransactionAbort):
        c.write_objects(list(items))
    committed = set()
    for node in c.nodes.values():
        committed.update(node.shard.omap.keys())
    assert committed == {"p0", "p1", "p2"}
    assert c.stats.writes_ok == 3 and c.stats.writes_failed == 0
    c.fault_injector = None
    for name, data in items[:3]:
        assert c.read_object(name) == data


def test_coalesced_replace_survives_earlier_commit_failure():
    """A name-replace later in the batch must NOT lose its previous version
    when an *earlier* object's commit fails: the old refs are released only
    at commit time, so the aborted tail leaves the prior version readable —
    exactly like the serial loop that never reached it."""
    from repro.core import TransactionAbort

    rng = np.random.default_rng(31)
    old = rng.bytes(4096)
    c = DedupCluster.create(4, chunking=CH, batch_unicasts=True)
    c.write_object("b", old)
    c.tick(2)

    def abort_a(event, ctx):
        if event == "before_omap" and ctx.get("name") == "a":
            raise TransactionAbort("injected")

    c.fault_injector = abort_a
    with pytest.raises(WriteError):
        c.write_objects([("a", rng.bytes(4096)), ("b", rng.bytes(4096))])
    c.fault_injector = None
    assert c.read_object("b") == old  # previous version intact
    for node in c.nodes.values():
        for fp, e in node.shard.cit.items():
            assert e.refcount in (0, 1)  # rolled-back garbage or old refs


def test_lost_omap_probe_fails_replace_instead_of_leaking_refs():
    """If every OMAP probe of the write path's idempotence/replace check is
    lost, the write must FAIL — assuming 'absent' would skip releasing the
    replaced version's refs, leaking refcounts GC can never reclaim."""
    from repro.core import OmapGet

    c = DedupCluster.create(3, chunking=CH)
    data_v1 = RNG.bytes(4096)
    c.write_object("x", data_v1)
    c.tick(2)

    def drop_write_path_probes(src, dst, msg, now):
        # the write path probes from the primary; client probes (reads) pass
        if isinstance(msg, OmapGet) and src != "client":
            return ("drop", 0)
        return ("deliver", 0)

    c.transport.policy = drop_write_path_probes
    with pytest.raises(WriteError):
        c.write_object("x", RNG.bytes(4096))
    c.transport.policy = reliable()
    assert c.read_object("x") == data_v1  # old version intact
    total_refs = sum(
        e.refcount for n in c.nodes.values() for e in n.shard.cit.values()
    )
    assert total_refs == 4  # v1's four chunks, exactly once each
    # and a clean delete still reclaims everything
    c.delete_object("x")
    c.tick(20); c.run_gc(); c.tick(20); c.run_gc()
    assert c.unique_bytes_stored() == 0


def test_nodedup_baseline_rewrite_replaces():
    from repro.core import NoDedupCluster

    c = NoDedupCluster.create(3)
    c.write_object("x", b"version-1")
    c.write_object("x", b"version-2!")
    assert c.read_object("x") == b"version-2!"


# ------------------------------------------------ consistency-manager batch
def test_register_many_and_coalesced_drain():
    from repro.core.consistency import ConsistencyManager
    from repro.core.dmshard import DMShard

    sh = DMShard()
    fps = [sha256_fp(bytes([i]) * 8) for i in range(3)]
    for fp in fps:
        e = sh.cit_insert(fp, 8, now=0)
        e.refcount = 1
    cm = ConsistencyManager()
    cm.register_many(fps, now=0, txn_id=1)
    cm.register(fps[0], now=0, txn_id=2)  # duplicate flip for fps[0]
    assert cm.pending() == 4
    applied = cm.drain(sh, now=5)
    assert applied == 3                    # one flip per unique fingerprint
    assert cm.flips_coalesced == 1
    assert all(sh.cit_lookup(fp).is_valid() for fp in fps)
    assert cm.pending() == 0
