"""Training loop + dedup checkpointing integration."""

import os

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro.checkpoint import CheckpointConfig, DedupCheckpointer
from repro.configs import get_config
from repro.core import ChunkingSpec, DedupCluster, TransactionAbort, WriteError
from repro.data import SyntheticLMData
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.train import TrainConfig, train_loop
from repro.train.loop import build_train_step, init_train_state

CH = ChunkingSpec("fixed", 64 * 1024)


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_config("qwen2.5-32b").reduced()
    model = build_model(cfg)
    data = SyntheticLMData(vocab=cfg.vocab, seq_len=32, global_batch=4, seed=1)
    return cfg, model, data


def test_loss_decreases(tiny_setup):
    cfg, model, data = tiny_setup
    tc = TrainConfig(steps=25, log_every=1,
                     opt=AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=25))
    state, hist = train_loop(model, data, tc)
    first = np.mean([h["loss"] for h in hist[:4]])
    last = np.mean([h["loss"] for h in hist[-4:]])
    assert last < first - 0.1, (first, last)


def test_grad_accum_matches_full_batch(tiny_setup):
    cfg, model, data = tiny_setup
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    state1 = init_train_state(model, jax.random.PRNGKey(0), opt)
    state2 = jax.tree.map(lambda x: x, state1)
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    s1, _ = jax.jit(build_train_step(model, opt, accum=1))(state1, batch)
    s2, _ = jax.jit(build_train_step(model, opt, accum=2))(state2, batch)
    for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-3)


def test_gradient_compression_error_feedback():
    opt = AdamWConfig(lr=1e-2, compress_grads=True, warmup_steps=1, total_steps=5)
    params = {"w": jnp.ones((64, 64), jnp.float32)}
    state = adamw_init(params, opt)
    grads = {"w": jnp.full((64, 64), 1e-3, jnp.float32)}
    p2, s2, m = adamw_update(params, grads, state, opt)
    assert "err" in s2 and float(jnp.sum(jnp.abs(s2["err"]["w"]))) >= 0.0
    assert not np.array_equal(np.asarray(p2["w"]), np.asarray(params["w"]))
    # error feedback: non-uniform grads leave quantization residuals that
    # accumulate instead of vanishing (uniform tensors quantize losslessly)
    tiny = {"w": jnp.asarray(np.random.default_rng(0).normal(0, 1e-6, (64, 64)), jnp.float32)}
    _, s3, _ = adamw_update(p2, tiny, s2, opt)
    assert np.abs(np.asarray(s3["err"]["w"])).max() > 0


def test_checkpoint_roundtrip_bitexact(tiny_setup):
    cfg, model, data = tiny_setup
    opt = AdamWConfig()
    state = init_train_state(model, jax.random.PRNGKey(3), opt)
    ck = DedupCheckpointer(DedupCluster.create(4, replicas=2, chunking=CH))
    ck.save("s1", state)
    restored = ck.restore("s1", like=state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        a, b = np.asarray(a), np.asarray(b)
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(
            a.view(np.uint8) if a.dtype.name == "bfloat16" else a,
            b.view(np.uint8) if b.dtype.name == "bfloat16" else b,
        )


def test_checkpoint_dedup_across_saves(tiny_setup):
    cfg, model, data = tiny_setup
    params = model.init(jax.random.PRNGKey(0))
    ck = DedupCheckpointer(DedupCluster.create(4, chunking=CH))
    ck.save("a", params)
    ck.save("b", params)  # identical -> ref-only writes, ~50% savings
    assert ck.stats["leaves_ref_only"] > 0
    assert ck.cluster.space_savings() > 0.45
    pa = ck.restore("a", like=params)
    pb = ck.restore("b", like=params)
    for x, y in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        np.testing.assert_array_equal(
            np.asarray(x).view(np.uint8), np.asarray(y).view(np.uint8))


def test_checkpoint_delete_keeps_referenced_chunks(tiny_setup):
    cfg, model, data = tiny_setup
    params = model.init(jax.random.PRNGKey(0))
    ck = DedupCheckpointer(DedupCluster.create(4, chunking=CH))
    ck.save("a", params)
    ck.save("b", params)
    ck.delete("a")
    restored = ck.restore("b", like=params)  # must survive a's deletion
    for x, y in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(
            np.asarray(x).view(np.uint8), np.asarray(y).view(np.uint8))


def test_crash_mid_save_older_checkpoint_safe(tiny_setup):
    cfg, model, data = tiny_setup
    params = model.init(jax.random.PRNGKey(0))
    cluster = DedupCluster.create(4, replicas=2, chunking=CH)
    ck = DedupCheckpointer(cluster, CheckpointConfig(device_fp_fastpath=False))
    ck.save("good", params)
    calls = {"n": 0}

    def inj(event, ctx):
        if event == "before_chunk_op":
            calls["n"] += 1
            if calls["n"] == 29:
                raise TransactionAbort("host died mid-checkpoint")

    cluster.fault_injector = inj
    mutated = jax.tree.map(lambda x: x + 1 if x.dtype != jnp.int32 else x, params)
    try:
        ck.save("crashy", mutated)
    except WriteError:
        pass
    cluster.fault_injector = None
    restored = ck.restore("good", like=params)
    for x, y in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(
            np.asarray(x).view(np.uint8), np.asarray(y).view(np.uint8))
    # garbage from the failed save is collectable
    cluster.tick(20); cluster.run_gc(); cluster.tick(20)
    cluster.run_gc()
    restored2 = ck.restore("good", like=params)  # still intact post-GC
    assert restored2 is not None


def test_restore_with_node_down_uses_replicas(tiny_setup):
    cfg, model, data = tiny_setup
    params = model.init(jax.random.PRNGKey(0))
    cluster = DedupCluster.create(5, replicas=2, chunking=CH)
    ck = DedupCheckpointer(cluster)
    ck.save("s", params)
    cluster.crash_node(list(cluster.nodes)[1])
    restored = ck.restore("s", like=params)
    for x, y in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(
            np.asarray(x).view(np.uint8), np.asarray(y).view(np.uint8))
