"""Message-driven recovery subsystem: digest wire model, digest-diff
repair (the scrub contract), the cluster-wide refcount audit, and the
split-brain convergence property.

Acceptance invariant (ISSUE 4): for seeded schedules, partition ->
divergent writes on both sides -> heal -> recovery round (digest repair +
refcount audit + GC) yields cluster state byte-identical to a
never-partitioned oracle — including a schedule where a ``TxnCancel`` is
fully lost after an applied-but-unacked op (the PR 3 residual leak).
Recovery traffic is ordinary transport traffic: it appears in
``net_bytes``/``EdgeStats``, is subject to delivery policies, and its
mutating messages ride the per-node seen-windows.

The split-brain sweep is seeded and parametrized; widen it with
``RECOVERY_SCHEDULES=100 pytest tests/test_recovery.py -k split_brain``
and reproduce a nightly failure with ``RECOVERY_SEED_BASE=<seed>
RECOVERY_SCHEDULES=1`` (the failing parametrization id IS the seed).
"""

import inspect
import os

import numpy as np
import pytest

from conftest import assert_seen_window_margin
from repro.core import (
    CONTROL_MSG_BYTES,
    DIGEST_ENTRY_BYTES,
    DIGEST_GROUP_BYTES,
    OMAP_DIGEST_ENTRY_BYTES,
    RECIPE_REF_BYTES,
    TOMBSTONE_RECORD_BYTES,
    ChunkOpBatch,
    ChunkingSpec,
    CITEntry,
    DecrefBatch,
    DedupCluster,
    DigestReply,
    DigestRequest,
    OmapPut,
    ReadError,
    RecoveryRound,
    RefAudit,
    RepairChunk,
    RepairDaemon,
    TxnCancel,
    WriteError,
    chaos,
    duplicate,
    partition,
    place,
    reliable,
    sha256_fp,
)

CH = ChunkingSpec("fixed", 1024)


def pytest_generate_tests(metafunc):
    """Split-brain schedules are seeded: the fast path runs a fixed set of
    20, the nightly recovery-convergence sweep widens it via
    RECOVERY_SCHEDULES / RECOVERY_SEED_BASE."""
    if "split_seed" in metafunc.fixturenames:
        base = int(os.environ.get("RECOVERY_SEED_BASE", "0"))
        n = int(os.environ.get("RECOVERY_SCHEDULES", "20"))
        metafunc.parametrize("split_seed", range(base, base + n))


# ----------------------------------------------------------------- helpers
def cluster_state(c):
    state = {}
    for nid, n in c.nodes.items():
        cit = {fp: (e.refcount, e.flag, e.size) for fp, e in n.shard.cit.items()}
        # versions and deleted_at are clock/txn-counter artifacts that may
        # legitimately differ between a cluster and its oracle; the deleted
        # FLAG is state (a tombstone is not a live empty object)
        omap = {
            name: (e.object_fp, tuple(e.chunk_fps), e.size, e.deleted)
            for name, e in n.shard.omap.items()
        }
        state[nid] = (cit, omap, dict(n.chunk_store))
    return state


def settle(c, ticks: int = 40, gc_rounds: int = 3):
    c.tick(ticks)
    for _ in range(gc_rounds):
        c.run_gc()
        c.tick(c.nodes[next(iter(c.nodes))].gc.threshold + 1)
    c.run_gc()


def total_refs(c):
    return sum(e.refcount for n in c.nodes.values() for e in n.shard.cit.values())


def applied_unacked_lost_cancel(src, dst, msg, now):
    """The PR 3 residual-leak schedule: every chunk batch APPLIES but its
    ack is lost, and the compensating TxnCancel is itself fully lost — the
    refs it took leak until a refcount audit reconciles them."""
    if isinstance(msg, ChunkOpBatch):
        return ("ack_drop", 0)
    if isinstance(msg, TxnCancel):
        return ("drop", 0)
    return ("deliver", 0)


# --------------------------------------------------------- digest wire model
def test_recovery_message_wire_model():
    fp = sha256_fp(b"z" * 64)
    req = DigestRequest(kind="chunks")
    summary = DigestReply(kind="chunks", groups={("a", "b"): (2, 123)}, entries={})
    assert req.response_payload_bytes(summary) == DIGEST_GROUP_BYTES
    assert req.wire_bytes("oss1", summary) == CONTROL_MSG_BYTES + DIGEST_GROUP_BYTES
    detail = DigestReply(
        kind="chunks", groups={}, entries={fp: (True, True, 1, 1, 100)}
    )
    assert req.response_payload_bytes(detail) == DIGEST_ENTRY_BYTES
    recipes = DigestReply(kind="recipes", groups={}, entries={fp: 3})
    assert (
        DigestRequest(kind="recipes").response_payload_bytes(recipes)
        == RECIPE_REF_BYTES
    )
    omap_detail = DigestReply(kind="omap", groups={}, entries={"name": fp})
    assert (
        DigestRequest(kind="omap").response_payload_bytes(omap_detail)
        == OMAP_DIGEST_ENTRY_BYTES
    )
    # repair moves pay for the bytes they ship; metadata-only repairs and
    # audit corrections are control-only
    assert RepairChunk(fp, b"x" * 100, None).payload_bytes("oss1") == 100
    assert RepairChunk(fp, None, CITEntry(1, 1, 100)).payload_bytes("oss1") == 0
    audit = RefAudit(((fp, 2),))
    assert audit.wire_bytes("oss1") == CONTROL_MSG_BYTES
    assert audit.lookups() == 1


def test_digest_probes_stay_out_of_seen_window():
    """DigestRequest is a read: recording probes would let recovery
    traffic evict mutating message ids from the bounded windows."""
    c = DedupCluster.create(3, replicas=2, chunking=CH)
    c.write_object("a", np.random.default_rng(0).bytes(4096))
    c.tick(3)
    filled = {nid: len(n.seen) for nid, n in c.nodes.items()}
    assert c.scrub() == 0  # healthy cluster: digests agree, no repairs
    assert c.transport.msgs_by_type["digest_request"] >= 3
    for nid, n in c.nodes.items():
        assert len(n.seen) == filled[nid]


def test_recovery_traffic_is_transport_traffic():
    """Digest probes and repairs are wire traffic: counted in net_bytes
    and visible per edge — nothing about recovery is free or omniscient."""
    c = DedupCluster.create(3, replicas=2, chunking=CH)
    c.write_object("a", np.random.default_rng(1).bytes(8192))
    c.tick(3)
    victim = c.chunk_targets(sha256_fp(c.read_object("a")[:1024]))[0]
    c.nodes[victim].chunk_store.clear()
    c.nodes[victim].shard.cit.clear()
    before = c.stats.net_bytes
    restored = c.scrub()
    assert restored > 0
    assert c.stats.net_bytes > before
    assert c.transport.msgs_by_type["digest_request"] > 0
    assert c.transport.msgs_by_type["repair_chunk"] >= restored
    probe_edges = [e for (s, _), e in c.transport.edges.items() if s == "recovery"]
    assert probe_edges and sum(e.msgs for e in probe_edges) > 0
    repair_edges = [
        e
        for (s, d), e in c.transport.edges.items()
        if s in c.nodes and d in c.nodes and s != d and e.payload_bytes
    ]
    assert repair_edges, "repair bytes must flow on node-to-node edges"


def test_cluster_scrub_has_no_direct_state_reads():
    """The acceptance criterion, structurally: cluster.py's scrub/repair
    paths contain zero direct cross-node state reads — they delegate to
    the message-driven recovery subsystem."""
    for fn in (DedupCluster.scrub, DedupCluster.recover, DedupCluster.set_map):
        src = inspect.getsource(fn)
        for forbidden in ("chunk_store", ".shard", ".cit", "cit_lookup"):
            assert forbidden not in src, (fn.__name__, forbidden)


# ------------------------------------------------------- digest-diff repair
def test_scrub_restores_bytes_and_cit_after_disk_loss():
    c = DedupCluster.create(4, replicas=2, chunking=CH)
    rng = np.random.default_rng(2)
    objs = {f"o{i}": rng.bytes(4096) for i in range(8)}
    c.write_objects(list(objs.items()))
    c.tick(3)
    victim = "oss1"
    c.nodes[victim].chunk_store.clear()
    c.nodes[victim].shard.cit.clear()
    restored = c.scrub()
    assert restored > 0
    c.tick(2)
    for nid, node in c.nodes.items():
        for fp in node.chunk_store:
            for t in c.chunk_targets(fp):
                assert fp in c.nodes[t].chunk_store
                assert c.nodes[t].shard.cit_lookup(fp) is not None
    for name, data in objs.items():
        assert c.read_object(name) == data


def test_repair_source_prefers_holder_with_cit_entry():
    """Regression for the old scrub's have[0] bug: it snapshotted the CIT
    entry from the first byte-holder even when that holder had no entry
    while another did. The digest path picks per-resource sources: bytes
    from a byte-holder, the CIT snapshot from a holder that HAS the entry."""
    c = DedupCluster.create(3, replicas=2, chunking=CH)
    blob = np.random.default_rng(3).bytes(1024)  # exactly one chunk
    c.write_object("a", blob)
    c.tick(3)
    fp = sha256_fp(blob)
    t1, t2 = c.chunk_targets(fp)
    # partial loss, split across the replica set: t1 keeps only the bytes,
    # t2 keeps only the CIT entry
    c.nodes[t1].shard.cit_remove(fp)
    del c.nodes[t2].chunk_store[fp]
    restored = c.scrub()
    assert restored == 1  # t2's byte copy
    e1 = c.nodes[t1].shard.cit_lookup(fp)
    assert e1 is not None and e1.refcount == 1, (
        "t1 must adopt the CIT entry from the holder that has it"
    )
    assert fp in c.nodes[t2].chunk_store
    assert c.read_object("a") == blob
    # and the audit agrees the repaired state is exact
    rep = RecoveryRound(c)
    rep.audit_refcounts()
    assert rep.report.corrections == 0


def test_recovery_mutating_messages_ride_the_seen_window():
    """RepairChunk / RefAudit / audit DecrefBatch delivered twice must be
    state no-ops the second time: a recovery round under duplicate(1.0)
    converges to the same state as a reliable one."""

    def build():
        c = DedupCluster.create(3, replicas=2, chunking=CH)
        rng = np.random.default_rng(4)
        c.transport.policy = applied_unacked_lost_cancel
        for i in range(2):  # leaked refs -> audit decref work
            with pytest.raises(WriteError):
                c.write_object(f"leak{i}", rng.bytes(3072))
        c.transport.policy = reliable()
        c.write_objects([(f"o{i}", rng.bytes(3072)) for i in range(4)])
        c.tick(3)
        victim = sorted(c.nodes)[0]  # missing replica -> RepairChunk work
        c.nodes[victim].chunk_store.clear()
        c.nodes[victim].shard.cit.clear()
        return c

    ref, dup = build(), build()
    ref.recover()
    dup.transport.policy = duplicate(
        1.0, seed=5, only=(RepairChunk, RefAudit, DecrefBatch)
    )
    dup.transport.retry_budget = 2
    report = dup.recover()
    dup.transport.policy = reliable()
    dup.transport.retry_budget = 0
    assert report.chunks_repaired > 0 and report.refs_over > 0
    assert dup.transport.late_deliveries > 0
    assert sum(n.stats.dup_msgs_suppressed for n in dup.nodes.values()) > 0
    settle(ref), settle(dup)
    assert cluster_state(dup) == cluster_state(ref)


# ----------------------------------------------------------- refcount audit
def test_audit_reclaims_lost_txn_cancel_leak():
    """THE residual window PR 3 documented: op applied, ack lost, and the
    conditional TxnCancel itself fully lost. The leaked references are
    invisible to GC (refcount > 0) until the audit walks the recipes and
    proves no object accounts for them."""
    oracle = DedupCluster.create(3, chunking=CH)
    c = DedupCluster.create(3, chunking=CH)
    data = np.random.default_rng(13).bytes(4096)
    c.transport.policy = applied_unacked_lost_cancel
    with pytest.raises(WriteError):
        c.write_object("x", data)
    assert total_refs(c) > 0, "the leak: applied refs, no recipe, no cancel"
    assert all(not n.shard.omap for n in c.nodes.values())
    c.transport.policy = reliable()
    # the client retries; the leaked entries double-count as dedup hits
    c.write_object("x", data)
    oracle.write_object("x", data)
    settle(c), settle(oracle)
    assert cluster_state(c) != cluster_state(oracle), (
        "without the audit the leak persists forever (GC cannot touch "
        "refcount>0 entries)"
    )
    report = c.recover()
    assert report.refs_over > 0
    settle(c), settle(oracle)
    assert cluster_state(c) == cluster_state(oracle)
    assert c.read_object("x") == data


def test_audit_decref_skips_gc_aging_via_cross_match_feed():
    """References the audit proved unreferenced enter the GC held set
    pre-aged: the next sweep reclaims them with NO aging wait (the recipe
    walk is the cross-match evidence), and still-queued async flips for
    them are purged."""
    c = DedupCluster.create(3, chunking=CH)
    c.transport.policy = applied_unacked_lost_cancel
    with pytest.raises(WriteError):
        c.write_object("leak", np.random.default_rng(14).bytes(4096))
    c.transport.policy = reliable()
    leaked = total_refs(c)
    assert leaked > 0
    r = RecoveryRound(c)
    r.collect_digests()
    r.repair_chunks()
    r.audit_refcounts()
    assert r.report.refs_over == leaked
    assert sum(n.cm.flips_purged for n in c.nodes.values()) > 0
    # ONE sweep, zero ticks of aging: audit-fed entries collect immediately
    removed = sum(len(fps) for fps in c.run_gc().values())
    assert removed > 0
    assert sum(n.gc.audit_fed for n in c.nodes.values()) == removed
    assert total_refs(c) == 0
    assert all(not n.chunk_store for n in c.nodes.values())


def test_audit_restores_missing_refs_and_flags():
    """A replica that missed increfs (and whose flag flip was lost) is
    raised back to the recipe-proven count through RefAudit."""
    c = DedupCluster.create(3, replicas=2, chunking=CH)
    blob = np.random.default_rng(15).bytes(1024)
    c.write_object("a", blob)
    c.write_object("b", blob)  # shared chunk: refcount 2 on both replicas
    c.tick(3)
    fp = sha256_fp(blob)
    t1, _ = c.chunk_targets(fp)
    from repro.core import INVALID

    c.nodes[t1].shard.cit_lookup(fp).refcount = 0  # lost both increfs
    c.nodes[t1].shard.cit_set_flag(fp, INVALID, c.now)  # and the flag
    rep = c.recover()
    assert rep.refs_under == 2
    e = c.nodes[t1].shard.cit_lookup(fp)
    assert e.refcount == 2 and e.is_valid()
    assert c.read_object("a") == blob


def test_audit_skipped_when_a_recipe_digest_is_lost():
    """Safety gate: partial recipe knowledge would release references
    belonging to the unheard node's objects — the audit refuses to run."""
    c = DedupCluster.create(3, replicas=2, chunking=CH)
    c.write_objects(
        [(f"o{i}", np.random.default_rng(16).bytes(3072)) for i in range(4)]
    )
    c.tick(3)
    refs = total_refs(c)

    def drop_recipe_probes(src, dst, msg, now):
        if isinstance(msg, DigestRequest) and msg.kind == "recipes":
            return ("drop", 0)
        return ("deliver", 0)

    c.transport.policy = drop_recipe_probes
    r = RecoveryRound(c)
    assert r.audit_refcounts() == 0
    assert r.report.audit_skipped
    assert r.report.unreachable >= 1
    assert total_refs(c) == refs, "a skipped audit must correct nothing"


# ------------------------------------------------ rebalance-during-recovery
def test_rebalance_during_recovery_round():
    """set_map() landing between digest collection and repair: placement
    is re-resolved at send time, so a migrated chunk is neither repaired
    to its stale target nor double-counted, and a subsequent audit (fresh
    collection) sees a fixed point."""
    c = DedupCluster.create(4, replicas=2, chunking=CH)
    rng = np.random.default_rng(17)
    objs = {f"o{i}": rng.bytes(4096) for i in range(10)}
    c.write_objects(list(objs.items()))
    c.tick(3)
    victim = "oss2"
    c.nodes[victim].chunk_store.clear()
    c.nodes[victim].shard.cit.clear()
    r = RecoveryRound(c)
    r.collect_digests()          # digests describe the 4-node placement
    c.add_node()                 # topology change + migration IN FLIGHT
    r.repair_chunks()            # stale digests, fresh placement
    c.tick(2)
    # nothing repaired off-placement, nothing double-stored
    for nid, node in c.nodes.items():
        for fp in node.chunk_store:
            assert nid in place(fp, c.cmap), f"stray copy of {fp} on {nid}"
        for fp in node.shard.cit:
            assert nid in place(fp, c.cmap), f"stray CIT entry {fp} on {nid}"
    # a FRESH full round finishes the job and reaches a fixed point
    c.recover()
    rep2 = c.recover()
    assert rep2.chunks_repaired == 0
    assert rep2.corrections == 0
    assert rep2.omap_repaired == 0
    for name, data in objs.items():
        assert c.read_object(name) == data


def test_omap_authority_is_version_not_placement_order():
    """A primary that was down across a replace holds the OLD version;
    placement-order authority would resurrect it cluster-wide. The commit
    version (bumped by every replace) elects the survivor instead."""
    c = DedupCluster.create(4, replicas=2, chunking=CH)
    old = np.random.default_rng(41).bytes(2048)
    new = np.random.default_rng(42).bytes(2048)
    c.write_object("victim", old)
    c.tick(3)
    from repro.core import name_fp

    primary = place(name_fp("victim"), c.cmap)[0]
    c.crash_node(primary)
    c.write_object("victim", new)  # commits on the survivors, version 2
    c.tick(3)
    c.restart_node(primary)        # stale version-1 replica rejoins
    report = c.recover()
    assert report.omap_repaired >= 1
    settle(c)
    assert c.read_object("victim") == new, (
        "recovery must never roll back a committed replace"
    )
    for nid in place(name_fp("victim"), c.cmap):
        e = c.nodes[nid].shard.omap_get("victim")
        assert e is not None and e.version == 2


def test_audit_skipped_when_omap_repair_lost_probes():
    """The symmetric safety gate: a lost OMAP digest probe means a replica
    that silently missed commits may be elected recipe owner with
    incomplete recipes — the round's audit must not run."""
    c = DedupCluster.create(3, replicas=2, chunking=CH)
    c.write_objects(
        [(f"o{i}", np.random.default_rng(45).bytes(3072)) for i in range(4)]
    )
    c.tick(3)
    refs = total_refs(c)

    def drop_omap_probes(src, dst, msg, now):
        if isinstance(msg, DigestRequest) and msg.kind == "omap":
            return ("drop", 0)
        return ("deliver", 0)

    c.transport.policy = drop_omap_probes
    report = c.recover()
    assert report.audit_skipped
    assert total_refs(c) == refs, "a gated audit must correct nothing"
    # with the network healthy again the next round audits normally
    c.transport.policy = reliable()
    report = c.recover()
    assert not report.audit_skipped


def test_delete_recreate_beats_stale_replica_version():
    """Versions are the committing transaction's cluster-monotonic id, so
    a delete+recreate always outranks a stale replica's pre-delete entry —
    a per-name counter would restart at 1 and lose to it."""
    from repro.core import name_fp

    c = DedupCluster.create(4, replicas=2, chunking=CH)
    rng = np.random.default_rng(46)
    v1, v2, fresh = rng.bytes(2048), rng.bytes(2048), rng.bytes(2048)
    c.write_object("x", v1)
    c.write_object("x", v2)  # stale replicas will hold this higher-txn entry
    c.tick(3)
    primary = place(name_fp("x"), c.cmap)[0]
    c.crash_node(primary)    # keeps the v2 entry through the delete+recreate
    c.delete_object("x")
    c.write_object("x", fresh)
    c.tick(3)
    c.restart_node(primary)
    c.recover()
    settle(c)
    assert c.read_object("x") == fresh, (
        "a stale pre-delete entry must never outrank the recreated one"
    )


def test_failed_replace_commit_keeps_previous_version():
    """A replace failing at (or after) the before_omap point must leave
    the previous version fully readable: old refs are released only after
    the commit record is written."""
    from repro.core import TransactionAbort

    c = DedupCluster.create(3, replicas=2, chunking=CH)
    old = np.random.default_rng(47).bytes(2048)
    c.write_object("x", old)
    c.tick(3)
    refs_before = total_refs(c)

    def abort_commit(event, ctx):
        if event == "before_omap" and ctx.get("name") == "x":
            raise TransactionAbort("injected at commit")

    c.fault_injector = abort_commit
    with pytest.raises(WriteError):
        c.write_object("x", np.random.default_rng(48).bytes(2048))
    c.fault_injector = None
    assert c.read_object("x") == old
    settle(c)  # the failed attempt's chunks age out as garbage
    assert c.read_object("x") == old
    assert total_refs(c) == refs_before


def test_rebalance_keeps_local_copy_until_a_move_is_acked():
    """A lossy policy that eats every MigrateChunk must not let set_map
    destroy the last surviving copy: the source retains it (stray holder)
    and the digest repair round re-ships it once the network heals."""
    from repro.core import MigrateChunk, OmapPut

    c = DedupCluster.create(3, replicas=1, chunking=CH)
    rng = np.random.default_rng(43)
    objs = {f"o{i}": rng.bytes(3072) for i in range(6)}
    c.write_objects(list(objs.items()))
    c.tick(3)

    def eat_moves(src, dst, msg, now):
        if isinstance(msg, (MigrateChunk, OmapPut)) and getattr(msg, "migrate", True):
            return ("drop", 0)
        return ("deliver", 0)

    c.transport.policy = eat_moves
    c.add_node()  # every move is lost — nothing may be destroyed
    total_chunks = sum(len(n.chunk_store) for n in c.nodes.values())
    assert total_chunks > 0
    c.transport.policy = reliable()
    report = c.recover()  # stray holders re-ship to the new placement
    assert report.chunks_repaired > 0
    assert report.omap_repaired > 0
    c.tick(2)
    for name, data in objs.items():
        assert c.read_object(name) == data


def test_explicit_zero_retry_budget_wins_over_injected_transport():
    """retry_budget=0 / ack_timeout=2 passed explicitly must override an
    injected transport's settings; omitting them inherits the transport's."""
    from repro.core import Transport
    from repro.core.node import StorageNode

    nodes = {f"oss{i}": StorageNode(f"oss{i}") for i in range(2)}
    from repro.core import ClusterMap

    cmap = ClusterMap(epoch=1, nodes=tuple(nodes), replicas=1)
    t = Transport(handlers=nodes, retry_budget=3, ack_timeout=7)
    inherited = DedupCluster(cmap=cmap, nodes=nodes, transport=t, chunking=CH)
    assert inherited.retry_budget == 3 and inherited.ack_timeout == 7
    t2 = Transport(handlers=nodes, retry_budget=3, ack_timeout=7)
    explicit = DedupCluster(
        cmap=cmap, nodes=nodes, transport=t2, chunking=CH,
        retry_budget=0, ack_timeout=2,
    )
    assert explicit.retry_budget == 0 and explicit.ack_timeout == 2
    assert t2.retry_budget == 0 and t2.ack_timeout == 2


def test_unrecoverable_bytes_still_repairs_surviving_cit_entries():
    """Bytes lost on every holder: the byte copy is unrecoverable, but a
    surviving CIT entry still propagates so the group's digests converge
    (otherwise every future round re-expands the group into details)."""
    c = DedupCluster.create(3, replicas=2, chunking=CH)
    blob = np.random.default_rng(44).bytes(1024)
    c.write_object("a", blob)
    c.tick(3)
    fp = sha256_fp(blob)
    t1, t2 = c.chunk_targets(fp)
    del c.nodes[t1].chunk_store[fp]   # bytes gone everywhere
    del c.nodes[t2].chunk_store[fp]
    c.nodes[t2].shard.cit_remove(fp)  # entry survives only on t1
    r = RecoveryRound(c)
    r.collect_digests()
    r.repair_chunks()
    assert r.report.unrecoverable > 0
    assert c.nodes[t2].shard.cit_lookup(fp) is not None, (
        "the surviving CIT entry must still reach the other target"
    )
    # with both replicas digesting identically now, the next round is clean
    r2 = RecoveryRound(c)
    r2.collect_digests()
    assert r2.repair_chunks() == 0
    assert r2.report.groups_mismatched == 0


# ------------------------------------- tombstones & always-on recovery
def test_tombstone_wire_model():
    fp = sha256_fp(b"t" * 64)
    req = DigestRequest(kind="omap", since_epoch=3)
    # omap detail entries are (object_fp, version, deleted, deleted_at)
    detail = DigestReply(kind="omap", groups={}, entries={"n": (fp, 4, False, None)})
    assert req.response_payload_bytes(detail) == OMAP_DIGEST_ENTRY_BYTES
    # aged-tombstone listings ride summary replies, one record each
    summary = DigestReply(
        kind="omap", groups={("a", "b"): (1, 9)}, entries={},
        tombstones={"gone": (7, 100), "also": (9, 120)},
    )
    assert req.response_payload_bytes(summary) == (
        DIGEST_GROUP_BYTES + 2 * TOMBSTONE_RECORD_BYTES
    )
    # chunk detail carries the mtime column the concurrent audit gates on
    chunk_detail = DigestReply(
        kind="chunks", groups={}, entries={fp: (True, True, 1, 1, 100, 5)}
    )
    assert (
        DigestRequest(kind="chunks").response_payload_bytes(chunk_detail)
        == DIGEST_ENTRY_BYTES
    )


def test_stale_put_cannot_resurrect_tombstone():
    """Receiver-side version gate: a delayed OmapPut carrying the
    pre-delete entry must not clobber the newer tombstone."""
    from repro.core import name_fp

    c = DedupCluster.create(3, replicas=2, chunking=CH)
    data = np.random.default_rng(50).bytes(2048)
    c.write_object("x", data)
    c.tick(2)
    targets = place(name_fp("x"), c.cmap)
    stale = c.nodes[targets[0]].shard.omap_get("x")
    assert c.delete_object("x")
    refused_before = sum(n.stats.stale_puts_refused for n in c.nodes.values())
    applied, prev = c.transport.send("client", targets[0], OmapPut(stale), c.now)
    assert applied is False and prev is None
    assert (
        sum(n.stats.stale_puts_refused for n in c.nodes.values())
        == refused_before + 1
    )
    e = c.nodes[targets[0]].shard.omap_get("x")
    assert e is not None and e.deleted
    with pytest.raises(ReadError):
        c.read_object("x")


def test_tombstone_reap_requires_full_ack():
    """A replica that missed the delete blocks the reap: round 1 repairs
    the tombstone onto it (version beats the stale live entry — no
    resurrection), and only round 2 — every live target listing the aged
    tombstone — reaps it everywhere."""
    from repro.core import name_fp

    c = DedupCluster.create(4, replicas=2, chunking=CH)
    data = np.random.default_rng(51).bytes(2048)
    c.write_object("x", data)
    c.tick(2)
    targets = place(name_fp("x"), c.cmap)
    c.crash_node(targets[1])          # this replica misses the delete
    assert c.delete_object("x")
    horizon = max(n.gc.tombstone_horizon for n in c.nodes.values())
    c.tick(horizon + 1)
    c.restart_node(targets[1])        # rejoins holding the stale live entry
    r1 = c.recover()
    assert r1.tombstones_reaped == 0, (
        "reap requires EVERY live target to have listed the aged tombstone; "
        "the rejoiner only adopted it this round"
    )
    e = c.nodes[targets[1]].shard.omap_get("x")
    assert e is not None and e.deleted, "repair must propagate the tombstone"
    with pytest.raises(ReadError):
        c.read_object("x")
    r2 = c.recover()
    assert r2.tombstones_reaped > 0
    for n in c.nodes.values():
        assert "x" not in n.shard.omap, "fully-acked aged tombstone must reap"


def test_delete_failure_before_tombstone_leaves_object_intact():
    """Mid-delete failure, phase 1: nothing committed -> the object stays
    fully readable with its refs untouched."""
    from repro.core import TransactionAbort

    c = DedupCluster.create(3, replicas=2, chunking=CH)
    data = np.random.default_rng(52).bytes(3072)
    c.write_object("x", data)
    c.tick(2)
    refs = total_refs(c)

    def boom(event, ctx):
        if event == "before_tombstone":
            raise TransactionAbort("injected before the tombstone commit")

    c.fault_injector = boom
    with pytest.raises(TransactionAbort):
        c.delete_object("x")
    c.fault_injector = None
    assert c.read_object("x") == data
    assert total_refs(c) == refs
    settle(c)
    assert c.read_object("x") == data


def test_delete_failure_after_tombstone_is_fully_tombstoned():
    """Mid-delete failure, phase 2: the tombstone committed but the refs
    were never released — the name reads as deleted (never a half-released
    recipe), and the leaked refs are exactly what the audit reclaims."""
    from repro.core import TransactionAbort

    c = DedupCluster.create(3, replicas=2, chunking=CH)
    data = np.random.default_rng(53).bytes(3072)
    c.write_object("x", data)
    c.tick(2)

    def boom(event, ctx):
        if event == "before_delete_decref":
            raise TransactionAbort("injected before ref release")

    c.fault_injector = boom
    with pytest.raises(TransactionAbort):
        c.delete_object("x")
    c.fault_injector = None
    with pytest.raises(ReadError):
        c.read_object("x")
    rep = c.recover()
    assert rep.refs_over > 0, "the unreleased refs are audit-visible leaks"
    settle(c)
    assert total_refs(c) == 0
    assert sum(len(n.chunk_store) for n in c.nodes.values()) == 0


def test_cancelled_delete_restores_the_entry():
    """A delete whose every tombstone ack is lost rolls back: the
    conditional TxnCancel(undelete) restores the pre-delete entry
    receiver-side iff the tombstone is still in place at that exact
    version — a newer write racing in is left untouched."""
    from repro.core import OmapDelete

    c = DedupCluster.create(3, replicas=2, chunking=CH)
    data = np.random.default_rng(54).bytes(2048)
    c.write_object("x", data)
    c.tick(2)
    refs = total_refs(c)

    def eat_delete_acks(src, dst, msg, now):
        if isinstance(msg, OmapDelete):
            return ("ack_drop", 0)
        return ("deliver", 0)

    c.transport.policy = eat_delete_acks
    with pytest.raises(WriteError):
        c.delete_object("x")
    c.transport.policy = reliable()
    c.tick(2)
    assert c.read_object("x") == data, "cancelled delete must restore the entry"
    assert total_refs(c) == refs, "no refs may be released by a failed delete"


def test_incremental_rounds_redigest_strictly_fewer_groups():
    """The always-on win: a background round scoped by ``since_epoch``
    re-digests only groups dirtied since the last completed round — and
    still reaches the same fixed point as a quiesced full round."""
    c = DedupCluster.create(4, replicas=2, chunking=CH)
    rng = np.random.default_rng(55)
    c.write_objects([(f"o{i}", rng.bytes(3072)) for i in range(12)])
    c.tick(3)
    d = RepairDaemon(c)
    r1 = d.step()
    assert r1.groups_skipped == 0, "round 1 covers everything since epoch 0"
    c.tick(2)
    c.write_object("o3", rng.bytes(3072))  # dirty a slice of the cluster
    c.tick(2)
    r2 = d.step()
    assert r2.groups_skipped > 0, "clean groups must be skipped server-side"
    assert r2.groups_digested < r1.groups_digested, (
        "a partially-dirty cluster must re-digest strictly fewer groups"
    )
    c.tick(3)
    rep = c.recover()  # quiesced full round: nothing left to find
    assert rep.corrections == 0
    assert rep.chunks_repaired == 0
    assert rep.omap_repaired == 0


def test_incremental_round_repairs_a_crash_window():
    """Scoping by dirty epoch must not hide real divergence: a write that
    lands while one replica is down dirties the SURVIVORS' trackers; the
    incremental step's two-phase collection re-probes the rejoined member
    for those peer-reported groups (its own tracker thinks them clean) and
    repairs the crash window — no full round needed."""
    from repro.core import name_fp

    c = DedupCluster.create(3, replicas=2, chunking=CH)
    rng = np.random.default_rng(57)
    c.write_objects([(f"o{i}", rng.bytes(3072)) for i in range(6)])
    c.tick(3)
    d = RepairDaemon(c)
    d.step()                       # baseline: everything covered + settled
    c.tick(2)
    blob = rng.bytes(3072)
    targets = place(name_fp("fresh"), c.cmap)
    c.crash_node(targets[1])
    c.write_object("fresh", blob)  # commits on the survivors only
    c.tick(2)
    c.restart_node(targets[1])
    r = d.step()
    assert r.groups_skipped > 0, "untouched groups stay skipped"
    assert r.omap_repaired >= 1, (
        "the incremental step must repair the crash window by itself"
    )
    e = c.nodes[targets[1]].shard.omap_get("fresh")
    assert e is not None and not e.deleted
    assert c.read_object("fresh") == blob


def test_audit_defers_inflight_transaction():
    """An audit running concurrently with a write (refs taken, commit not
    yet landed) defers the young fingerprints instead of releasing them as
    leaks; without the gate the same audit misjudges them."""
    rng = np.random.default_rng(56)
    base, payload = rng.bytes(3072), rng.bytes(3072)
    observed: dict = {}

    c = DedupCluster.create(3, replicas=2, chunking=CH)
    c.write_object("a", base)
    c.tick(3)

    def audit_mid_txn(event, ctx):
        if event == "before_omap" and ctx.get("name") == "b" and not observed:
            r = RecoveryRound(c, exclude_after=c.now)
            r.audit_refcounts()
            observed["gated"] = r.report

    c.fault_injector = audit_mid_txn
    c.write_object("b", payload)
    c.fault_injector = None
    rep = observed["gated"]
    assert rep.audit_deferred > 0, "the in-flight txn's fps must be deferred"
    assert rep.refs_over == 0, "in-flight refs must not be misjudged as leaks"
    assert c.read_object("b") == payload
    c.tick(3)
    rep2 = c.recover()
    assert rep2.corrections == 0, "deferral left nothing broken behind"

    # Counterfactual: the identical audit WITHOUT the gate reads the
    # in-flight references as unaccounted leaks — the corruption the
    # exclude_after epoch exists to prevent.
    c2 = DedupCluster.create(3, replicas=2, chunking=CH)
    c2.write_object("a", base)
    c2.tick(3)
    observed2: dict = {}

    def audit_mid_txn_ungated(event, ctx):
        if event == "before_omap" and ctx.get("name") == "b" and not observed2:
            r = RecoveryRound(c2)  # no exclude_after: judge everything
            r.audit_refcounts()
            observed2["ungated"] = r.report

    c2.fault_injector = audit_mid_txn_ungated
    c2.write_object("b", payload)
    c2.fault_injector = None
    assert observed2["ungated"].refs_over > 0, (
        "without the gate the audit releases refs a transaction still owns"
    )


# ----------------------------------------------------- simtime link models
def test_per_edge_link_model_charges_the_straggler_nic():
    """``modeled_time_clusterwide`` defaults to a max-over-links network
    term (the straggler NIC from EdgeStats) instead of pretending every
    byte spreads uniformly over n NICs; the legacy model stays behind the
    ``link_model`` flag and both are pinned in the bench JSON."""
    import sys
    from pathlib import Path

    sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "benchmarks"))
    from simtime import DEFAULT, modeled_time_clusterwide, straggler_nic_seconds

    c = DedupCluster.create(4, replicas=2, chunking=CH)
    rng = np.random.default_rng(23)
    c.write_objects([(f"s{i}", rng.bytes(8192)) for i in range(8)])
    for i in range(4):
        c.read_object(f"s{i}")
    # the hottest NIC carries at least its fair share of the aggregate
    n = len(c.nodes)
    assert straggler_nic_seconds(c) >= c.stats.net_bytes / (
        n * DEFAULT.net_Bps_per_node
    )
    uniform = modeled_time_clusterwide(c, link_model="uniform")
    per_edge = modeled_time_clusterwide(c, link_model="per_edge")
    assert per_edge >= uniform  # a max can never beat the uniform split
    assert modeled_time_clusterwide(c) == per_edge  # per-edge is the default
    with pytest.raises(ValueError):
        modeled_time_clusterwide(c, link_model="nope")


# ------------------------------------------------- split-brain convergence
def _run_split_brain(split_seed: int) -> None:
    rng = np.random.default_rng(5000 + split_seed)
    oracle = DedupCluster.create(4, replicas=2, chunking=CH)
    c = DedupCluster.create(4, replicas=2, chunking=CH)

    base = [(f"base{i}", rng.bytes(3072)) for i in range(4)]
    for cl in (oracle, c):
        cl.write_objects(list(base))
        cl.tick(3)

    nodes = sorted(c.nodes)
    k = int(rng.integers(1, len(nodes)))
    side_a = tuple(sorted(rng.choice(nodes, size=k, replace=False)))
    side_b = tuple(n for n in nodes if n not in side_a)

    # Divergent writes on both sides of the partition: fresh names AND
    # replaces of pre-partition names (a committed replace leaves the
    # cross-side OMAP replica stale and its old chunk refs leaked there).
    items = [(f"w{i}", rng.bytes(1024 * int(rng.integers(2, 5)))) for i in range(8)]
    items += [("base0", rng.bytes(3072)), ("base2", rng.bytes(3072))]

    c.transport.policy = partition(side_a, side_b)
    failed = []
    for name, data in items:
        try:
            c.write_object(name, data)
        except WriteError:
            failed.append((name, data))
    for name, data in items:
        oracle.write_object(name, data)

    # Tombstone schedules: deletes riding the OPEN partition. The delete
    # commits its versioned tombstone on the primary's side only — the
    # cross-side OMAP replica keeps the stale live entry, which recovery
    # must beat by version (no resurrection). ``base3`` is additionally
    # recreated after heal: the recreate's higher version must beat the
    # tombstone right back, across the same split.
    deleted: list[str] = []
    recreated: tuple[str, bytes] | None = None
    if split_seed % 3 != 0:
        assert c.delete_object("base1")
        assert oracle.delete_object("base1")
        deleted.append("base1")
    if split_seed % 3 == 2:
        assert c.delete_object("base3")
        assert oracle.delete_object("base3")
        deleted.append("base3")
        recreated = ("base3", rng.bytes(2048))
    assert c.transport.dropped > 0, "the partition must sever something"

    # heal; the client retries what failed (idempotent writes: exact)
    c.transport.policy = reliable()
    for name, data in failed:
        c.write_object(name, data)
    if recreated is not None:
        c.write_object(*recreated)
        oracle.write_object(*recreated)

    if split_seed % 4 == 1:
        # fold in the PR 3 residual leak: applied-but-unacked op whose
        # TxnCancel is fully lost — recovery must reconcile this too
        c.transport.policy = applied_unacked_lost_cancel
        leak_item = ("leaky", rng.bytes(3072))
        with pytest.raises(WriteError):
            c.write_object(*leak_item)
        c.transport.policy = reliable()
        c.write_object(*leak_item)
        oracle.write_object(*leak_item)

    if split_seed % 2 == 1:
        # the recovery round itself runs under a PR 3 chaos policy
        c.transport.policy = chaos(
            seed=split_seed, p_drop=0.05, p_dup=0.1, p_reorder=0.05, p_ack_drop=0.08
        )
        c.transport.retry_budget = 12

    mid: tuple[str, bytes] | None = None
    if split_seed % 4 >= 2:
        # write DURING recovery: a live write lands between the round's
        # phases (what the always-on daemon interleaves with constantly);
        # the audit defers the write's freshly-touched fingerprints
        # (``exclude_after``) instead of misjudging them, and the follow-up
        # full round below finishes the fixed point. Odd seeds in this
        # bucket additionally ride the chaos policy set above.
        c.tick(1)
        r0 = RecoveryRound(c, exclude_after=c.now)
        r0.repair_omap()
        mid = ("mid", rng.bytes(2560))
        for _ in range(6):
            try:
                c.write_object(*mid)
                break
            except WriteError:
                continue
        oracle.write_object(*mid)
        r0.collect_digests()
        r0.repair_chunks()
        r0.audit_refcounts()
        r0.reap_tombstones()
        c.tick(1)
    report = c.recover()
    c.transport.policy = reliable()
    c.transport.retry_budget = 0

    # recovery traffic is accounted traffic
    assert c.transport.msgs_by_type.get("digest_request", 0) > 0
    assert any(s == "recovery" for (s, _) in c.transport.edges)
    assert not report.audit_skipped

    settle(oracle), settle(c)
    assert cluster_state(c) == cluster_state(oracle), (
        f"split-brain seed {split_seed} diverged from the never-partitioned "
        f"oracle (repro: RECOVERY_SEED_BASE={split_seed} RECOVERY_SCHEDULES=1)"
    )
    # measured seen-window margin at default sizing, even through recovery
    assert_seen_window_margin(c)

    expected = dict(items)
    if mid is not None:
        expected[mid[0]] = mid[1]
    if recreated is not None:
        expected[recreated[0]] = recreated[1]
    for name in deleted:
        if recreated is not None and name == recreated[0]:
            continue  # recreated: readable again, checked below
        with pytest.raises(ReadError):
            c.read_object(name)
        assert not c.delete_object(name), "tombstoned name must read as absent"
    for name, data in expected.items():
        assert c.read_object(name) == data

    # Age past the GC horizon on both sides: fully-acked tombstones reap
    # everywhere, recreated names survive, and the clusters still agree.
    still = [n for n in deleted if recreated is None or n != recreated[0]]
    if still:
        horizon = max(n.gc.tombstone_horizon for n in c.nodes.values())
        c.tick(horizon + 1)
        oracle.tick(horizon + 1)
        rep_c = c.recover()
        rep_o = oracle.recover()
        assert rep_c.tombstones_reaped > 0 and rep_o.tombstones_reaped > 0
        for name in still:
            for n in c.nodes.values():
                assert name not in n.shard.omap, "tombstone must be reaped"
            for n in oracle.nodes.values():
                assert name not in n.shard.omap
        if recreated is not None:
            assert c.read_object(recreated[0]) == recreated[1]
        assert cluster_state(c) == cluster_state(oracle)


def test_split_brain_recovery_converges_to_oracle(split_seed):
    _run_split_brain(split_seed)
