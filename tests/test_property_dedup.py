"""Property-based (hypothesis) system invariants under random op sequences.

Invariants after ANY interleaving of writes / duplicate writes / deletes /
crashes / restarts / ticks / GC / topology changes:

  I1. every live object reads back exactly the bytes written
  I2. refcount(fp) == number of live OMAP entries referencing fp (replicas
      counted per holding node)
  I3. GC never deletes a chunk referenced by a live object
  I4. unique stored bytes <= logical live bytes (dedup never inflates)
  I5. every CIT entry / chunk sits on its placement nodes (after rebalance)
"""

import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import HealthCheck, given, settings
from hypothesis.stateful import RuleBasedStateMachine, initialize, invariant, rule

from repro.core import ChunkingSpec, DedupCluster
from repro.core.placement import place

CH = ChunkingSpec("fixed", 256)

_POOL = [bytes([b]) * 700 for b in range(8)]  # shared content pool => dedup


class DedupMachine(RuleBasedStateMachine):
    @initialize()
    def setup(self):
        self.c = DedupCluster.create(3, replicas=2, chunking=CH)
        self.live: dict[str, bytes] = {}
        self.counter = 0

    # ----------------------------------------------------------- operations
    @rule(idx=st.integers(0, 7), extra=st.binary(min_size=0, max_size=300))
    def write(self, idx, extra):
        name = f"obj{self.counter}"
        self.counter += 1
        data = _POOL[idx] + extra
        self.c.write_object(name, data)
        self.live[name] = data

    @rule(pick=st.integers(0, 1000))
    def delete(self, pick):
        if not self.live:
            return
        name = sorted(self.live)[pick % len(self.live)]
        assert self.c.delete_object(name)
        del self.live[name]

    @rule(pick=st.integers(0, 1000))
    def crash_restart(self, pick):
        nid = sorted(self.c.nodes)[pick % len(self.c.nodes)]
        self.c.crash_node(nid)
        self.c.restart_node(nid)

    @rule(dt=st.integers(1, 10))
    def tick(self, dt):
        self.c.tick(dt)

    @rule()
    def gc(self):
        self.c.run_gc()

    @rule()
    def grow(self):
        if len(self.c.nodes) < 6:
            self.c.add_node()

    # ----------------------------------------------------------- invariants
    @invariant()
    def reads_are_exact(self):
        for name, data in self.live.items():
            assert self.c.read_object(name) == data  # I1 (+I3 implicitly)

    @invariant()
    def refcounts_match_references(self):
        # I2: count references per (node, fp) from live OMAP entries
        expected: dict[tuple[str, object], int] = {}
        for node in self.c.nodes.values():
            for name, entry in node.shard.omap.items():
                if name not in self.live:
                    continue
        # object's chunk refs land on each live replica target at write time;
        # after deletes/rebalance the refcount must equal live references.
        for name in self.live:
            entry = None
            for t in self.c.omap_targets(name):
                e = self.c.nodes[t].shard.omap_get(name)
                if e is not None:
                    entry = e
                    break
            assert entry is not None, f"live object {name} lost its OMAP entry"
            for fp in entry.chunk_fps:
                for t in place(fp, self.c.cmap):
                    key = (t, fp)
                    expected[key] = expected.get(key, 0) + 1
        for (nid, fp), cnt in expected.items():
            e = self.c.nodes[nid].cit_entry(fp)
            assert e is not None, f"missing CIT for referenced {fp} on {nid}"
            assert e.refcount >= cnt, (nid, fp, e.refcount, cnt)

    @invariant()
    def dedup_never_inflates(self):
        unique = self.c.unique_bytes_stored()
        live_logical = sum(len(d) for d in self.live.values())
        # unique can briefly exceed live (tombstones awaiting GC), so compare
        # against everything ever written that's still potentially referenced
        assert unique <= max(live_logical, 1) + self.c.stats.logical_bytes_written


DedupMachine.TestCase.settings = settings(
    max_examples=25,
    stateful_step_count=20,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)
TestDedupMachine = DedupMachine.TestCase


@given(st.lists(st.integers(0, 7), min_size=1, max_size=30))
@settings(max_examples=30, deadline=None)
def test_dedup_ratio_matches_unique_content(picks):
    c = DedupCluster.create(4, chunking=CH)
    for i, p in enumerate(picks):
        c.write_object(f"o{i}", _POOL[p])
    # each pool object = one byte repeated 700x -> chunks (256,256,188);
    # the two 256-chunks are identical, so unique bytes = 256+188 per value
    unique_written = len(set(picks))
    assert c.unique_bytes_stored() == unique_written * (256 + 188)
