"""Vectorized CDC vs the scalar reference oracle, the jnp/Pallas kernel
oracle, and the spec invariants (min/max size, losslessness)."""

import numpy as np
import pytest

from repro.core.chunking import (
    ChunkingSpec,
    cdc_mask,
    chunk_cdc,
    chunk_cdc_scalar,
    chunk_object,
    window_hash_at,
    window_hashes,
)

RNG = np.random.default_rng(1234)


# --------------------------------------------------------- window hashes ----
@pytest.mark.parametrize("n", [1, 31, 32, 33, 1000, 65536, 65537, 70000])
def test_window_hashes_match_scalar_oracle(n):
    data = RNG.bytes(n)
    h = window_hashes(data)
    idx = set(range(0, min(n, 64))) | {n - 1, n // 2, n // 3}
    for i in idx:
        assert int(h[i]) == window_hash_at(data, i), i


def test_window_hashes_empty():
    assert window_hashes(b"").shape == (0,)


def test_window_hashes_full_sweep_small():
    data = RNG.bytes(300)
    h = window_hashes(data)
    assert [int(x) for x in h] == [window_hash_at(data, i) for i in range(300)]


def test_window_hashes_kernel_backend_agrees():
    pytest.importorskip("jax")
    data = RNG.bytes(5000)
    np.testing.assert_array_equal(
        window_hashes(data), window_hashes(data, backend="kernel")
    )


def test_window_hashes_pallas_interpret_agrees():
    pytest.importorskip("jax")
    import jax.numpy as jnp

    from repro.core.chunking import _GEAR_NP
    from repro.kernels.cdc import cdc_hashes_pallas

    data = RNG.bytes(4096)
    buf = np.frombuffer(data, dtype=np.uint8)
    tvals = jnp.asarray(_GEAR_NP[buf])
    np.testing.assert_array_equal(
        np.asarray(cdc_hashes_pallas(tvals, interpret=True)), window_hashes(data)
    )


# ------------------------------------------------------------- boundaries ----
SPECS = [
    ChunkingSpec("cdc", 256),
    ChunkingSpec("cdc", 1024),
    ChunkingSpec("cdc", 2048),
    ChunkingSpec("cdc", 256, min_size=10, max_size=64),
    ChunkingSpec("cdc", 256, min_size=100, max_size=50),   # degenerate: max <= min
    ChunkingSpec("cdc", 512, min_size=1, max_size=8192),
]
SIZES = [0, 1, 17, 255, 256, 1000, 8192, 40000, 65535, 65536, 65537]


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: f"cs{s.chunk_size}-{s.min_size}-{s.max_size}")
def test_vectorized_boundaries_equal_scalar(spec):
    for n in SIZES:
        data = RNG.bytes(n)
        assert list(chunk_cdc(data, spec)) == list(chunk_cdc_scalar(data, spec)), n


def test_min_max_size_enforced_and_lossless():
    spec = ChunkingSpec("cdc", 256).normalized()
    for n in [1, 100, 5000, 50000]:
        data = RNG.bytes(n)
        chunks = chunk_object(data, spec)
        assert b"".join(chunks) == data
        assert all(len(c) <= spec.max_size for c in chunks)
        # every chunk except the tail respects min_size
        assert all(len(c) >= spec.min_size + 1 or c is chunks[-1] for c in chunks)


def test_repeated_content_shares_boundaries():
    """Identical tails re-synchronize: the vectorized chunker must keep the
    CDC shift-resilience property the checkpoint tests rely on."""
    base = RNG.bytes(30000)
    spec = ChunkingSpec("cdc", 512)
    a = set(chunk_object(base, spec))
    b = set(chunk_object(RNG.bytes(137) + base, spec))
    assert len(a & b) >= len(a) // 2


def test_kernel_backend_chunking_identical():
    pytest.importorskip("jax")
    data = RNG.bytes(20000)
    spec = ChunkingSpec("cdc", 512)
    assert list(chunk_cdc(data, spec)) == list(chunk_cdc(data, spec, backend="kernel"))


def test_cdc_mask_targets_chunk_size():
    assert cdc_mask(512 * 1024) == (1 << 19) - 1
    assert cdc_mask(256) == (1 << 8) - 1


# ------------------------------------------------- scalar-mask fast path ----
def test_mask_window_truncation_levels():
    from repro.core.chunking import _WINDOW, _mask_window

    assert _mask_window((1 << 1) - 1) == 1
    assert _mask_window((1 << 8) - 1) == 8
    assert _mask_window((1 << 11) - 1) == 16   # next pow2 >= 11
    assert _mask_window((1 << 16) - 1) == 16
    assert _mask_window((1 << 17) - 1) == _WINDOW   # too wide: full window
    assert _mask_window(0b1010) == _WINDOW          # non-scalar mask: full


@pytest.mark.parametrize("log2_target", [6, 8, 11, 14, 16, 17])
def test_truncated_scan_candidates_match_full_hashes(log2_target):
    """The fused tiled scan may stop the doubling scheme once the window
    covers every masked bit; candidates must equal the full-window ones."""
    from repro.core.chunking import _cdc_candidates

    mask = (1 << log2_target) - 1
    for n in [0, 100, 65535, 65537, 200000]:
        data = RNG.bytes(n)
        full = np.flatnonzero((window_hashes(data) & np.uint32(mask)) == 0)
        np.testing.assert_array_equal(_cdc_candidates(data, mask), full)


def test_small_mask_boundaries_equal_scalar_oracle():
    """End-to-end boundary equality vs chunk_cdc_scalar for small targets
    (the fast-path masks): bit-identical chunking."""
    for target in (128, 2048, 16 * 1024):
        spec = ChunkingSpec("cdc", target)
        for n in (0, 1, 5000, 70000):
            data = RNG.bytes(n)
            assert list(chunk_cdc(data, spec)) == list(chunk_cdc_scalar(data, spec)), (
                target,
                n,
            )


@pytest.mark.slow
def test_vectorized_boundaries_equal_scalar_big():
    data = RNG.bytes(1 << 20)
    spec = ChunkingSpec("cdc", 4096)
    assert list(chunk_cdc(data, spec)) == list(chunk_cdc_scalar(data, spec))
