"""Property tests: duplicate-delivery idempotency for EVERY message type.

Hypothesis drives the schedule space — which message types get duplicated,
with what in-flight lag, over which workload shape — and every schedule
must satisfy the invariant: delivering any subset of message types twice
(acks are never duplicated: one ack per delivery, duplicate deliveries
re-ack from the seen-window) leaves CIT refcounts, OMAP contents, chunk
stores and GC reachability byte-identical to a reliable-transport oracle
running the same workload.

The workload exercises every mutating message type at least once:
ChunkOpBatch (write), RefOnlyWrite (ref-write), DecrefBatch (delete),
OmapPut/OmapGet/OmapDelete (commit/probe/delete), MigrateChunk
(add_node + scrub), ChunkReadBatch (batched reads, the default restore
shape) and ChunkRead (the serial oracle shape).
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import HealthCheck, given, settings

from repro.core import (
    ChunkOpBatch,
    ChunkRead,
    ChunkReadBatch,
    ChunkingSpec,
    DecrefBatch,
    DedupCluster,
    MigrateChunk,
    OmapDelete,
    OmapGet,
    OmapPut,
    RefOnlyWrite,
    duplicate,
)

CH = ChunkingSpec("fixed", 512)

ALL_TYPES = (
    ChunkOpBatch,
    OmapPut,
    OmapGet,
    OmapDelete,
    DecrefBatch,
    RefOnlyWrite,
    ChunkRead,
    ChunkReadBatch,
    MigrateChunk,
)


def run_workload(c, rng_seed: int, n_objects: int, with_topology_change: bool):
    rng = np.random.default_rng(rng_seed)
    pool = [rng.bytes(1536) for _ in range(3)]
    items = [
        (f"o{i}", pool[i % len(pool)] + rng.bytes(512 * (i % 2)))
        for i in range(n_objects)
    ]
    c.write_objects(list(items))
    c.tick(3)
    c.write_object("o0", pool[1])                    # replace
    c.delete_object("o1")                            # delete -> DecrefBatch
    assert c.write_object_by_ref("ref", "o2") is not None   # RefOnlyWrite
    c.read_objects([name for name, _ in items[3:5]])  # ChunkReadBatch traffic
    c.batch_reads = False
    c.read_object(items[3][0])                       # serial ChunkRead traffic
    c.batch_reads = True
    if with_topology_change:
        c.add_node()                                 # MigrateChunk traffic
        c.scrub()
    c.tick(5)
    return items


def snapshot(c):
    state = {}
    for nid, n in c.nodes.items():
        state[nid] = (
            {fp: (e.refcount, e.flag, e.size) for fp, e in n.shard.cit.items()},
            {
                name: (e.object_fp, tuple(e.chunk_fps), e.size)
                for name, e in n.shard.omap.items()
            },
            dict(n.chunk_store),
        )
    return state


def settle(c):
    c.tick(15)
    for _ in range(2):
        c.run_gc()
        c.tick(12)
    c.run_gc()


@settings(
    max_examples=25,
    deadline=None,
    derandomize=True,
    suppress_health_check=[HealthCheck.too_slow],
)
@given(
    dup_types=st.sets(st.sampled_from(ALL_TYPES), min_size=1),
    lag=st.integers(1, 4),
    seed=st.integers(0, 10_000),
    n_objects=st.integers(4, 8),
    topo=st.booleans(),
)
def test_duplicating_any_message_subset_matches_oracle(
    dup_types, lag, seed, n_objects, topo
):
    oracle = DedupCluster.create(4, replicas=2, chunking=CH)
    dup = DedupCluster.create(
        4,
        replicas=2,
        chunking=CH,
        policy=duplicate(1.0, seed=seed, only=tuple(dup_types), lag=lag),
        retry_budget=2,
    )
    run_workload(oracle, seed, n_objects, topo)
    run_workload(dup, seed, n_objects, topo)
    settle(oracle)
    settle(dup)
    assert snapshot(dup) == snapshot(oracle)
    # GC reachability: a further full GC cycle is a fixed point on both
    removed = [fps for fps in dup.run_gc().values() if fps]
    assert not removed
    # acks are never duplicated: exactly one ack per delivery, and every
    # duplicate delivery was answered from a seen-window, not re-applied
    t = dup.transport
    assert t.acks_sent == t.deliveries
    if t.late_deliveries:
        assert sum(n.stats.dup_msgs_suppressed for n in dup.nodes.values()) > 0


@settings(max_examples=10, deadline=None, derandomize=True)
@given(seed=st.integers(0, 10_000), lag=st.integers(1, 3))
def test_duplicating_every_message_type_matches_oracle(seed, lag):
    """The all-types schedule the satellite names explicitly: every message
    delivered twice, acks never — full-state convergence with the oracle."""
    oracle = DedupCluster.create(3, replicas=2, chunking=CH)
    dup = DedupCluster.create(
        3,
        replicas=2,
        chunking=CH,
        policy=duplicate(1.0, seed=seed, lag=lag),
        retry_budget=2,
    )
    run_workload(oracle, seed, 6, True)
    run_workload(dup, seed, 6, True)
    settle(oracle)
    settle(dup)
    assert snapshot(dup) == snapshot(oracle)
    assert dup.transport.late_deliveries > 0
    suppressed = sum(n.stats.dup_msgs_suppressed for n in dup.nodes.values())
    assert suppressed > 0
