"""Sharding rules: spec inference, divisibility guards, logical axes."""

import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (
    ShardingRules,
    infer_param_spec,
    make_rules,
    param_specs_for_tree,
    shard,
    use_sharding_rules,
)

AX = {"pod": 2, "data": 16, "model": 16}


def rules():
    return ShardingRules(make_rules().rules, AX)


def test_embed_table_spec():
    s = infer_param_spec(("embed", "table"), (152064, 5120), rules())
    assert s == P("model", "data")


def test_embed_table_indivisible_vocab_guard():
    s = infer_param_spec(("embed", "table"), (50280, 2048), rules())
    assert s == P(None, "data")


def test_up_and_down_proj_specs():
    up = infer_param_spec(("blocks", "0", "attn", "wq", "w"), (64, 5120, 5120), rules())
    assert up == P(None, "data", "model")
    down = infer_param_spec(("blocks", "0", "attn", "wo", "w"), (64, 5120, 5120), rules())
    assert down == P(None, "model", "data")


def test_expert_specs():
    g = infer_param_spec(("blocks", "0", "moe", "experts", "gate"), (48, 16, 5120, 8192), rules())
    assert g == P(None, "model", "data", None)
    d = infer_param_spec(("blocks", "0", "moe", "experts", "down"), (48, 16, 8192, 5120), rules())
    assert d == P(None, "model", None, "data")


def test_indivisible_experts_guard():
    g = infer_param_spec(("moe", "experts", "gate"), (60, 2048, 1408), rules())
    assert g == P(None, "data", None)  # 60 % 16 != 0 -> replicate experts


def test_norm_replicated():
    s = infer_param_spec(("blocks", "0", "norm1", "scale"), (64, 5120), rules())
    assert s == P(None, None)


def test_bias_spec():
    s = infer_param_spec(("attn", "wq", "b"), (5120,), rules())
    assert s == P("model")


def test_activation_guard_drops_indivisible():
    r = rules()
    with use_sharding_rules(r):
        # 6 heads % 16 != 0 -> constraint dropped, no error
        x = jnp.zeros((2, 8, 6, 64))
        y = shard(x, "batch", "seq", "act_heads", None)
        assert y.shape == x.shape


def test_param_specs_for_tree_covers_whole_model():
    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config("qwen2-moe-a2.7b").reduced()
    m = build_model(cfg)
    tree = jax.eval_shape(lambda: m.init(jax.random.PRNGKey(0)))
    specs = param_specs_for_tree(tree, rules())
    leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert leaves and all(isinstance(s, P) for s in leaves)


def test_rules_decode_overrides():
    from repro.configs.base import SHAPES
    from repro.launch.mesh import make_production_mesh  # no device touch: fn only

    r = make_rules(kv_seq_axis="model")
    assert r.axis("kv_seq") == "model"
    r2 = make_rules(data_axes=None, kv_seq_axis=("data", "model"))
    assert r2.axis("batch") is None
