"""At-least-once transport: message ids, acks, timeout/retransmission, and
the idempotent receive paths that absorb duplicate and reordered delivery.

Proves the ROADMAP's standing claim — "the CIT's idempotent dedup_hit/repair
paths should absorb [duplicate-delivery windows] — worth proving with
tests" — as invariants:

* retransmission masks lost messages AND lost acks; a retransmitted
  delivery of an applied message is answered from the receiver's bounded
  seen-window without touching state;
* ``duplicate`` / ``reorder`` fault policies make the same message arrive
  twice and out of order; refcounts, OMAP contents, chunk stores and GC
  results still converge byte-identically to a reliable-transport oracle;
* when the retry budget runs out the sender distinguishes "op lost"
  (``maybe_applied=False`` — nothing to undo) from "ack lost, op applied?"
  (``maybe_applied=True`` — settled receiver-side by a conditional
  ``TxnCancel`` that compensates if the op applied and poisons the message
  id if a copy is still in flight);
* retried commits neither double-increment refcounts nor re-roll-back a
  committed object.

The chaos convergence test is seeded and parametrized; run more schedules
with ``CHAOS_SCHEDULES=150 pytest tests/test_at_least_once.py -k chaos``
and reproduce a nightly failure locally with ``CHAOS_SEED_BASE=<seed>
CHAOS_SCHEDULES=1`` (the failing parametrization id IS the seed).
"""

import os

import numpy as np
import pytest

from conftest import assert_seen_window_margin
from repro.core import (
    ChunkOpBatch,
    ChunkingSpec,
    DecrefBatch,
    DedupCluster,
    MessageDropped,
    OmapPut,
    ReadError,
    SeenWindow,
    Transport,
    UnsupportedTransportPolicy,
    WriteError,
    ack_loss,
    chaos,
    drop,
    duplicate,
    reliable,
    reorder,
    sha256_fp,
)

CH = ChunkingSpec("fixed", 1024)


def pytest_generate_tests(metafunc):
    """Chaos schedules are seeded: the fast path runs a fixed small set,
    the nightly job widens it via CHAOS_SCHEDULES / CHAOS_SEED_BASE. A
    failing test id names the seed to reproduce with."""
    if "chaos_seed" in metafunc.fixturenames:
        base = int(os.environ.get("CHAOS_SEED_BASE", "0"))
        n = int(os.environ.get("CHAOS_SCHEDULES", "20"))
        metafunc.parametrize("chaos_seed", range(base, base + n))


# ----------------------------------------------------------------- helpers
def cluster_state(c, with_store: bool = True):
    """Comparable snapshot: CIT (refcount, flag, size), OMAP layouts, and
    optionally the stored chunk bytes, per node."""
    state = {}
    for nid, n in c.nodes.items():
        cit = {fp: (e.refcount, e.flag, e.size) for fp, e in n.shard.cit.items()}
        omap = {
            name: (e.object_fp, tuple(e.chunk_fps), e.size, e.deleted)
            for name, e in n.shard.omap.items()
        }
        store = dict(n.chunk_store) if with_store else None
        state[nid] = (cit, omap, store)
    return state


def settle(c, ticks: int = 40, gc_rounds: int = 3):
    """Land in-flight copies, drain flips, and run GC to a fixed point."""
    c.tick(ticks)
    for _ in range(gc_rounds):
        c.run_gc()
        c.tick(c.nodes[next(iter(c.nodes))].gc.threshold + 1)
    c.run_gc()


def total_refs(c):
    return sum(e.refcount for n in c.nodes.values() for e in n.shard.cit.values())


# ------------------------------------------------- envelope/ack wire model
def test_every_delivery_is_acked_on_the_reverse_edge():
    c = DedupCluster.create(3, chunking=CH)
    data = np.random.default_rng(0).bytes(4096)
    c.write_object("a", data)
    t = c.transport
    assert t.acks_sent == t.deliveries == t.messages_sent
    assert t.ack_bytes == 64 * t.acks_sent
    # acks appear in EdgeStats on the reverse of each data edge
    for (src, dst), e in t.edges.items():
        if e.msgs:
            rev = t.edges.get((dst, src))
            assert rev is not None and rev.acks >= e.msgs
    # and they are part of net_bytes (visible through ClusterStats)
    assert c.stats.ack_bytes == t.ack_bytes
    assert c.stats.net_bytes > c.stats.logical_bytes_written


def test_out_of_order_arrival_is_counted():
    """A duplicated copy of message N lands after message N+1 on the same
    edge: its sequence number is below the receiver's high-water mark, the
    arrival is counted out-of-order, and the seen-window suppresses it."""
    from repro.core import OmapDelete
    from repro.core.node import StorageNode

    node = StorageNode("oss0")
    t = Transport(handlers={"oss0": node}, policy=duplicate(1.0))
    t.send("client", "oss0", OmapDelete("a"), 0)  # dup copy of seq 0 held
    t.send("client", "oss0", OmapDelete("b"), 0)  # seq 1 delivers, then flushes seq 0
    t.advance(5)
    assert node.stats.out_of_order >= 1
    assert node.stats.dup_msgs_suppressed >= 1
    assert t.late_deliveries >= 1


def test_reads_stay_out_of_the_seen_window():
    """ChunkRead/ChunkReadBatch/OmapGet are not recorded: read traffic must
    not evict mutating message ids from the bounded window (a duplicate
    read is harmless to re-serve; a duplicate ref increment is not)."""
    c = DedupCluster.create(2, chunking=CH)
    for node in c.nodes.values():
        node.seen.capacity = 4
    data = np.random.default_rng(30).bytes(2048)
    c.write_object("x", data)
    filled = {nid: len(n.seen) for nid, n in c.nodes.items()}
    for _ in range(25):  # heavy batched read traffic (the default shape)
        assert c.read_object("x") == data
    c.batch_reads = False
    for _ in range(25):  # and the serial per-chunk oracle shape
        assert c.read_object("x") == data
    assert c.transport.msgs_by_type["chunk_read_batch"] > 0
    assert c.transport.msgs_by_type["chunk_read"] > 0
    for nid, n in c.nodes.items():
        assert len(n.seen) == filled[nid], "reads must not consume window slots"


def test_sequence_numbers_are_per_edge_monotonic():
    c = DedupCluster.create(3, chunking=CH)
    c.write_object("a", np.random.default_rng(1).bytes(4096))
    for (_, _), e in c.transport.edges.items():
        assert e.next_seq >= 0
    # receiver-side high-water marks match what each edge sent
    for nid, node in c.nodes.items():
        for src, hi in node._edge_seq_seen.items():
            assert hi == c.transport.edges[(src, nid)].next_seq - 1


# ------------------------------------------------------- retransmission
def test_retry_budget_masks_drops_and_counts_retransmits():
    oracle = DedupCluster.create(4, replicas=2, chunking=CH)
    c = DedupCluster.create(
        4, replicas=2, chunking=CH, policy=drop(0.4, seed=11), retry_budget=8
    )
    rng = np.random.default_rng(2)
    items = [(f"o{i}", rng.bytes(4096)) for i in range(6)]
    oracle.write_objects(list(items))
    c.write_objects(list(items))
    assert c.stats.retransmits > 0
    assert c.stats.msgs_dropped > 0
    assert c.stats.timeout_ticks_waited == c.stats.retransmits * c.ack_timeout
    # logical message count is NOT inflated by retries
    assert c.stats.control_msgs == oracle.stats.control_msgs
    settle(oracle), settle(c)
    assert cluster_state(c) == cluster_state(oracle)
    for n, d in items:
        assert c.read_object(n) == d


def test_retransmitted_write_registers_flips_at_the_later_receive_time():
    """A write whose first attempts were dropped lands ack_timeout*k ticks
    later — its async commit flips become due later too, exactly like a
    delayed message."""
    c = DedupCluster.create(
        3,
        chunking=CH,
        policy=drop(1.0, seed=0, only=(ChunkOpBatch,)),
        retry_budget=3,
        ack_timeout=5,
    )
    # all 4 attempts drop -> WriteError; now allow the LAST attempt through
    attempts = {"n": 0}

    def drop_first_three(src, dst, msg, now):
        if isinstance(msg, ChunkOpBatch):
            attempts["n"] += 1
            if attempts["n"] % 4 != 0:
                return ("drop", 0)
        return ("deliver", 0)

    c.transport.policy = drop_first_three
    data = np.random.default_rng(3).bytes(2048)  # 2 chunks
    c.write_object("x", data)
    assert c.stats.retransmits >= 3
    c.tick(2)  # enough for an undelayed write's flips
    invalid = sum(len(n.shard.invalid_fps()) for n in c.nodes.values())
    assert invalid > 0, "flips must still be pending behind the retry delay"
    c.tick(20)
    assert sum(len(n.shard.invalid_fps()) for n in c.nodes.values()) == 0
    assert c.read_object("x") == data


def test_exhausted_retry_budget_raises_and_rolls_back():
    c = DedupCluster.create(
        3, chunking=CH, policy=drop(1.0, only=(ChunkOpBatch,)), retry_budget=2
    )
    with pytest.raises(WriteError):
        c.write_object("x", np.random.default_rng(4).bytes(4096))
    assert c.stats.writes_failed == 1
    n_batches = c.transport.msgs_by_type["chunk_op_batch"]
    assert c.stats.retransmits == 2 * n_batches
    # every attempt of an exhausted send waits out its ack timeout,
    # including the final one: (budget + 1) timeouts per lost send
    assert c.stats.timeout_ticks_waited == 3 * n_batches * c.ack_timeout
    assert total_refs(c) == 0
    assert all(not n.shard.omap for n in c.nodes.values())


# --------------------------------------------- duplicate delivery windows
def test_duplicate_everything_matches_reliable_oracle():
    """`duplicate(1.0)`: every unicast arrives twice (the second copy late
    and out of order). The per-node seen-window answers every duplicate
    from cache; refcounts, OMAP, chunk stores and GC match the oracle."""
    rng = np.random.default_rng(5)
    blob = rng.bytes(4096)
    items = [(f"o{i}", rng.bytes(4096)) for i in range(6)] + [
        ("dupA", blob),
        ("dupB", blob),  # intra-batch duplicate content -> ref-only ops
    ]
    oracle = DedupCluster.create(4, replicas=2, chunking=CH)
    c = DedupCluster.create(
        4, replicas=2, chunking=CH, policy=duplicate(1.0, seed=6), retry_budget=2
    )
    oracle.write_objects(list(items))
    c.write_objects(list(items))
    # the duplicate copies really were delivered, and really were suppressed
    assert c.transport.late_deliveries > 0
    suppressed = sum(n.stats.dup_msgs_suppressed for n in c.nodes.values())
    assert suppressed > 0
    # delete + ref-write + rebalance under continued duplication
    for cc in (oracle, c):
        cc.delete_object("o0")
        assert cc.write_object_by_ref("ref", "o1") is not None
        cc.add_node()
        cc.scrub()
    settle(oracle), settle(c)
    assert cluster_state(c) == cluster_state(oracle)
    for n, d in items[1:]:
        assert c.read_object(n) == d
    assert c.read_object("ref") == c.read_object("o1")


def test_duplicated_decref_cannot_double_release():
    """DecrefBatch applied twice would corrupt refcounts (or assert on a
    negative count). The seen-window makes the duplicate a no-op."""
    c = DedupCluster.create(
        3, chunking=CH, policy=duplicate(1.0, only=(DecrefBatch,)), retry_budget=1
    )
    blob = np.random.default_rng(7).bytes(1024)
    c.write_object("a", blob)
    c.write_object("b", blob)  # refcount 2 on the shared chunk
    c.tick(3)
    c.delete_object("a")
    c.tick(3)  # flushes the duplicate DecrefBatch copy
    refs = [e.refcount for n in c.nodes.values() for e in n.shard.cit.values()]
    assert refs == [1], f"duplicate decref must not double-release: {refs}"
    assert c.read_object("b") == blob


def test_duplicated_commit_does_not_double_release_replaced_version():
    """Rewriting a name releases the previous version's refs exactly once,
    even when every OmapPut (the commit record) is delivered twice."""
    c = DedupCluster.create(
        3, chunking=CH, policy=duplicate(1.0, only=(OmapPut,)), retry_budget=1
    )
    rng = np.random.default_rng(8)
    v1, v2 = rng.bytes(2048), rng.bytes(2048)
    c.write_object("x", v1)
    c.tick(3)
    refs_v1 = total_refs(c)
    c.write_object("x", v2)  # replace: releases v1 refs once at commit
    settle(c)
    assert c.read_object("x") == v2
    # v1 chunks fully released (flag-0, then GCed); v2 holds the only refs
    assert total_refs(c) == refs_v1
    assert all(e.refcount == 1 for n in c.nodes.values() for e in n.shard.cit.values())


# -------------------------------------------------------------- reordering
def test_reorder_held_original_lands_as_stale_duplicate():
    """`reorder` holds the original back; the sender times out and
    retransmits. The retransmission applies; the late original is a stale
    duplicate the seen-window suppresses."""
    oracle = DedupCluster.create(3, chunking=CH)
    c = DedupCluster.create(
        3, chunking=CH, policy=reorder(0.3, seed=9), retry_budget=8
    )
    rng = np.random.default_rng(9)
    items = [(f"r{i}", rng.bytes(4096)) for i in range(6)]
    oracle.write_objects(list(items))
    c.write_objects(list(items))
    assert c.transport.reordered > 0
    assert c.stats.retransmits > 0
    assert c.transport.late_deliveries > 0
    settle(oracle), settle(c)
    assert cluster_state(c) == cluster_state(oracle)


def test_reorder_without_budget_poisons_the_inflight_copy():
    """Budget 0: the sender gives up on a held (in-flight) message and
    cancels it. The cancel poisons the message id, so when the held copy
    finally lands it is DISCARDED — the cancelled transaction cannot
    resurrect."""
    c = DedupCluster.create(
        3, chunking=CH, policy=reorder(1.0, only=(ChunkOpBatch,)), retry_budget=0
    )
    with pytest.raises(WriteError):
        c.write_object("x", np.random.default_rng(10).bytes(4096))
    c.transport.policy = reliable()
    c.tick(5)  # lands every held copy -> poisoned -> discarded
    discarded = sum(n.stats.poisoned_discards for n in c.nodes.values())
    assert discarded > 0
    assert total_refs(c) == 0
    assert all(not n.chunk_store for n in c.nodes.values()), (
        "a poisoned chunk batch must not store bytes"
    )
    assert all(not n.shard.omap for n in c.nodes.values())
    # and a clean retry works
    data = np.random.default_rng(10).bytes(4096)
    c.write_object("x", data)
    assert c.read_object("x") == data


# --------------------------------------- "ack lost" vs "op lost" ambiguity
def test_ack_loss_with_budget_applies_exactly_once():
    """Lost acks are indistinguishable from lost messages at the sender;
    the retransmission is answered from the seen-window, so state mutates
    exactly once per message id."""
    oracle = DedupCluster.create(3, chunking=CH)
    c = DedupCluster.create(
        3, chunking=CH, policy=ack_loss(0.5, seed=12), retry_budget=6
    )
    rng = np.random.default_rng(12)
    items = [(f"a{i}", rng.bytes(4096)) for i in range(6)]
    oracle.write_objects(list(items))
    c.write_objects(list(items))
    assert c.transport.acks_dropped > 0
    assert c.stats.retransmits > 0
    suppressed = sum(n.stats.dup_msgs_suppressed for n in c.nodes.values())
    assert suppressed > 0, "retransmits of applied messages answered from cache"
    settle(oracle), settle(c)
    assert cluster_state(c) == cluster_state(oracle)


def test_op_applied_but_unacked_is_cancelled_not_leaked():
    """Budget 0 + total ack loss on chunk batches: the op APPLIED but the
    sender cannot know ("maybe_applied"). The conditional TxnCancel finds
    the id in the receiver's seen-window and compensates the refs — without
    it the applied refs would leak forever (refcount>0, no OMAP entry, so
    GC could never reclaim the bytes once the flip lands)."""
    c = DedupCluster.create(
        3, chunking=CH, policy=ack_loss(1.0, only=(ChunkOpBatch,)), retry_budget=0
    )
    with pytest.raises(WriteError):
        c.write_object("x", np.random.default_rng(13).bytes(4096))
    # the ops really applied (bytes hit disks) ...
    assert sum(n.stats.chunk_writes for n in c.nodes.values()) > 0
    cancels = sum(n.stats.cancels_applied for n in c.nodes.values())
    assert cancels > 0
    # ... and the cancel released every ref they took
    assert total_refs(c) == 0
    c.transport.policy = reliable()
    settle(c)
    assert all(not n.chunk_store for n in c.nodes.values()), (
        "cancelled refs age into garbage and GC reclaims the bytes"
    )


def test_op_lost_sends_no_cancel():
    """A pure drop (maybe_applied=False) needs no compensation — nothing
    reached the receiver, so no TxnCancel message is spent on it."""
    c = DedupCluster.create(
        3, chunking=CH, policy=drop(1.0, only=(ChunkOpBatch,)), retry_budget=1
    )
    with pytest.raises(WriteError):
        c.write_object("x", np.random.default_rng(14).bytes(4096))
    assert c.transport.msgs_by_type.get("txn_cancel", 0) == 0
    assert total_refs(c) == 0


def test_unacked_commit_record_is_cancelled_conditionally():
    """All OmapPut acks lost with no budget: the commit may or may not have
    applied. The cancel removes a committed-looking entry (and the poison
    blocks an in-flight one), so a failed write NEVER leaves a readable
    object behind — while the chunk refs are rolled back."""
    c = DedupCluster.create(
        3, chunking=CH, policy=ack_loss(1.0, only=(OmapPut,)), retry_budget=0
    )
    with pytest.raises(WriteError):
        c.write_object("x", np.random.default_rng(15).bytes(4096))
    c.transport.policy = reliable()
    assert all(not n.shard.omap for n in c.nodes.values()), (
        "maybe-applied commit record must be compensated away"
    )
    assert total_refs(c) == 0
    settle(c)
    assert all(not n.chunk_store for n in c.nodes.values())


def test_retried_commit_is_idempotent():
    """OmapPut ack lost, budget covers it: the retransmission re-acks from
    the seen-window. The commit applies once — the replaced version's refs
    are released exactly once, nothing double-increments, and the object
    stays committed (no spurious rollback)."""
    c = DedupCluster.create(3, replicas=2, chunking=CH, retry_budget=4)
    rng = np.random.default_rng(16)
    v1, v2 = rng.bytes(2048), rng.bytes(2048)
    c.write_object("x", v1)
    c.tick(3)
    c.transport.policy = ack_loss(0.6, seed=16, only=(OmapPut,))
    c.write_object("x", v2)  # replace under lossy commit acks
    c.transport.policy = reliable()
    settle(c)
    assert c.read_object("x") == v2
    assert all(
        e.refcount == 1 for n in c.nodes.values() for e in n.shard.cit.values()
    ), "replace must release v1 refs exactly once and take v2 refs exactly once"


# ------------------------------------------------------------ seen window
def test_seen_window_is_bounded():
    w = SeenWindow(capacity=8)
    for i in range(100):
        w.record(i, f"r{i}")
    assert len(w) == 8
    assert 99 in w and 92 in w and 91 not in w
    assert w.get(99) == "r99"
    assert w.get(0) is w.ABSENT


def test_node_seen_window_bounds_memory_under_load():
    c = DedupCluster.create(2, chunking=CH)
    for node in c.nodes.values():
        node.seen.capacity = 16
    rng = np.random.default_rng(17)
    c.write_objects([(f"o{i}", rng.bytes(2048)) for i in range(40)])
    for node in c.nodes.values():
        assert len(node.seen) <= 16
    # an undersized window shows visible eviction pressure — the counter the
    # sizing study reads (zero at default capacity, see the chaos test)
    assert c.stats.seen_evictions > 0
    assert c.stats.seen_high_water == 16
    pressured = [n for n in c.nodes.values() if n.stats.seen_evictions > 0]
    assert pressured and all(
        n.stats.seen_evictions == n.seen.evictions for n in c.nodes.values()
    )


# ------------------------------------------------------- chaos convergence
def test_chaos_schedule_converges_to_reliable_oracle(chaos_seed):
    """Acceptance invariant: under a seeded drop+duplicate+reorder+ack-loss
    schedule with retries enabled, a multi-object write_objects batch (plus
    delete / ref-write / replace traffic) converges to byte-identical CIT
    refcounts, OMAP state, chunk stores and GC results as the
    reliable-transport oracle. A WriteError under chaos is retried at the
    client (idempotent writes make the retry exact), mirroring real client
    behavior."""
    rng = np.random.default_rng(1000 + chaos_seed)
    pool = [rng.bytes(3072) for _ in range(4)]
    items = [
        (f"c{i}", pool[i % len(pool)] + rng.bytes(1024 * (i % 3)))
        for i in range(10)
    ]

    oracle = DedupCluster.create(4, replicas=2, chunking=CH)
    c = DedupCluster.create(
        4,
        replicas=2,
        chunking=CH,
        policy=chaos(
            seed=chaos_seed, p_drop=0.12, p_dup=0.15, p_reorder=0.08, p_ack_drop=0.1
        ),
        retry_budget=12,
    )

    def run(cluster):
        for attempt in range(6):
            try:
                cluster.write_objects(list(items))
                break
            except WriteError:
                continue
        else:
            raise AssertionError(
                f"chaos seed {chaos_seed}: batch did not commit in 6 client retries"
            )
        for attempt in range(6):
            try:
                cluster.delete_object("c1")
                break
            except WriteError:
                continue  # tombstone unacked under chaos: client retries
        for attempt in range(6):
            if cluster.write_object_by_ref("ref", "c2") is not None:
                break
        cluster.write_object("c3", pool[0])  # replace with different content

    run(oracle)
    run(c)
    settle(oracle), settle(c)
    assert cluster_state(c) == cluster_state(oracle), (
        f"chaos seed {chaos_seed} diverged from the reliable oracle "
        f"(repro: CHAOS_SEED_BASE={chaos_seed} CHAOS_SCHEDULES=1)"
    )
    # Measured seen-window margin at default sizing: zero evictions AND
    # peak occupancy within a stated fraction of capacity — a schedule that
    # merely avoided eviction while filling the window would still fail.
    margin = assert_seen_window_margin(c)
    assert margin > 0, "a chaos schedule must exercise the window at all"
    # GC reachability: another full GC cycle removes nothing on either side
    before = cluster_state(c)
    settle(oracle), settle(c)
    assert cluster_state(c) == before == cluster_state(oracle)
    for name, data in items:
        if name == "c1":
            continue
        expected = pool[0] if name == "c3" else data
        assert c.read_object(name) == expected


def test_read_chaos_batched_restore_matches_serial_oracle(chaos_seed):
    """Read-under-chaos: batched restores are byte-identical to the serial
    read oracle under drop / duplicate / reorder / combined-chaos policies
    (one family per seed, so the sweep covers each), and read traffic —
    retried, duplicated, or re-walked across replicas — neither consumes
    seen-window slots nor mutates converged cluster state."""
    rng = np.random.default_rng(2000 + chaos_seed)
    pool = [rng.bytes(2560) for _ in range(3)]
    items = [
        (f"r{i}", pool[i % len(pool)] + rng.bytes(512 * (i % 3)))
        for i in range(8)
    ]
    c = DedupCluster.create(4, replicas=2, chunking=CH)
    c.write_objects(list(items))
    settle(c)

    # serial oracle bytes, read on the still-reliable transport
    c.batch_reads = False
    oracle = [c.read_object(n) for n, _ in items]
    assert oracle == [d for _, d in items]
    c.batch_reads = True
    before = cluster_state(c)
    filled = {nid: len(n.seen) for nid, n in c.nodes.items()}

    policies = {
        "drop": drop(0.15, seed=chaos_seed),
        "duplicate": duplicate(0.25, seed=chaos_seed, lag=2),
        "reorder": reorder(0.2, seed=chaos_seed),
        "chaos": chaos(seed=chaos_seed, p_drop=0.12, p_dup=0.15,
                       p_reorder=0.08, p_ack_drop=0.1),
    }
    family = sorted(policies)[chaos_seed % len(policies)]
    c.transport.policy = policies[family]
    c.transport.retry_budget = 12

    names = [n for n, _ in items]
    for attempt in range(6):
        try:
            got = c.read_objects(names)
            break
        except ReadError:
            continue  # every replica walk lost under chaos: client retries
    else:
        raise AssertionError(
            f"read-chaos {family} seed {chaos_seed}: restore did not complete "
            f"in 6 client retries (repro: CHAOS_SEED_BASE={chaos_seed} "
            f"CHAOS_SCHEDULES=1)"
        )
    assert got == oracle, (
        f"read-chaos {family} seed {chaos_seed}: batched restore diverged "
        f"from the serial oracle (repro: CHAOS_SEED_BASE={chaos_seed} "
        f"CHAOS_SCHEDULES=1)"
    )
    # land late duplicate copies, then: reads mutated nothing, and no read
    # message id consumed a seen-window slot (reads stay out, like today)
    c.transport.policy = reliable()
    c.tick(30)
    assert cluster_state(c) == before
    for nid, n in c.nodes.items():
        assert len(n.seen) == filled[nid], "read chaos must not touch seen-windows"


# ------------------------------------------------------- baselines reject
def test_baselines_reject_lossy_policies():
    from repro.core import CentralDedupCluster, DiskLocalDedupCluster, NoDedupCluster

    for factory in (
        lambda: CentralDedupCluster.create(3),
        lambda: DiskLocalDedupCluster.create(3),
        lambda: NoDedupCluster.create(3),
    ):
        # constructor-time rejection
        proto = factory()
        with pytest.raises(UnsupportedTransportPolicy):
            type(proto)(cmap=proto.cmap, transport=Transport(policy=drop(0.5)))
        # post-construction swap caught at the next operation
        for bad in (drop(0.5), duplicate(0.5), reorder(0.5), ack_loss(0.5), chaos()):
            b = factory()
            b.transport.policy = bad
            with pytest.raises(UnsupportedTransportPolicy):
                b.write_object("x", b"payload")
        # a retry budget on a baseline transport is equally unsupported
        b = factory()
        b.transport.retry_budget = 3
        with pytest.raises(UnsupportedTransportPolicy):
            b.write_object("x", b"payload")
        # untagged custom callables cannot be proven lossless -> rejected
        b = factory()
        b.transport.policy = lambda src, dst, msg, now: ("deliver", 0)
        with pytest.raises(UnsupportedTransportPolicy):
            b.write_object("x", b"payload")
    # the reliable default still works everywhere
    ok = NoDedupCluster.create(3)
    ok.write_object("x", b"payload")
    assert ok.read_object("x") == b"payload"


def test_dedup_cluster_adopts_new_policies():
    """The new policies are first-class on DedupCluster.create (adopted,
    not rejected) — the counterpart of the baselines' explicit rejection."""
    for pol in (duplicate(0.3, seed=1), reorder(0.3, seed=1), ack_loss(0.3, seed=1),
                chaos(seed=1)):
        c = DedupCluster.create(3, chunking=CH, policy=pol, retry_budget=6)
        data = np.random.default_rng(20).bytes(4096)
        c.write_object("w", data)
        c.tick(5)
        assert c.read_object("w") == data


# ----------------------------------------------------------- simtime model
def test_simtime_charges_retries_and_acks():
    import sys
    from pathlib import Path

    sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "benchmarks"))
    from simtime import modeled_time_clusterwide

    rng = np.random.default_rng(21)
    items = [(f"s{i}", rng.bytes(4096)) for i in range(6)]
    a = DedupCluster.create(3, chunking=CH)
    b = DedupCluster.create(3, chunking=CH, policy=drop(0.4, seed=3), retry_budget=8)
    a.write_objects(list(items))
    b.write_objects(list(items))
    assert b.stats.retransmits > 0
    assert modeled_time_clusterwide(b) > modeled_time_clusterwide(a), (
        "retransmissions and ack timeouts must cost modeled time"
    )
