"""Pallas flash-attention kernel vs dense softmax oracle (interpret mode)."""

import math

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro.kernels.flash_attn import flash_attention_pallas

RNG = np.random.default_rng(11)


def _dense_ref(q, k, v, causal, window, scale):
    b, sq, h, hd = q.shape
    kh = k.shape[2]
    rep = h // kh
    kx = jnp.repeat(k, rep, axis=2)
    vx = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bshd->bhqs", q.astype(jnp.float32), kx.astype(jnp.float32)) * scale
    qi = jnp.arange(sq)[:, None]
    ki = jnp.arange(k.shape[1])[None, :]
    ok = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        ok = ok & (ki <= qi)
    if window:
        ok = ok & (ki > qi - window)
    s = jnp.where(ok[None, None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqs,bshd->bqhd", w, vx.astype(jnp.float32))


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
@pytest.mark.parametrize("h,kh", [(4, 4), (4, 2), (8, 1)])
def test_flash_matches_dense(causal, window, h, kh):
    b, sq, hd = 2, 256, 32
    q = jnp.asarray(RNG.standard_normal((b, sq, h, hd)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, sq, kh, hd)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, sq, kh, hd)), jnp.float32)
    scale = 1.0 / math.sqrt(hd)
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 blk_q=64, blk_kv=64, interpret=True)
    ref = _dense_ref(q, k, v, causal, window, scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_dtypes(dtype):
    b, sq, h, kh, hd = 1, 128, 2, 2, 64
    q = jnp.asarray(RNG.standard_normal((b, sq, h, hd))).astype(dtype)
    k = jnp.asarray(RNG.standard_normal((b, sq, kh, hd))).astype(dtype)
    v = jnp.asarray(RNG.standard_normal((b, sq, kh, hd))).astype(dtype)
    out = flash_attention_pallas(q, k, v, blk_q=64, blk_kv=64, interpret=True)
    ref = _dense_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                     v.astype(jnp.float32), True, 0, 1.0 / math.sqrt(hd))
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               rtol=tol, atol=tol)
    assert out.dtype == dtype


def test_flash_cross_block_shapes():
    """Non-square: Sq != Skv (e.g. suffix prefill against a longer cache)."""
    b, sq, skv, h, kh, hd = 1, 64, 256, 2, 1, 32
    q = jnp.asarray(RNG.standard_normal((b, sq, h, hd)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, skv, kh, hd)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, skv, kh, hd)), jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=False, blk_q=32, blk_kv=64,
                                 interpret=True)
    ref = _dense_ref(q, k, v, False, 0, 1.0 / math.sqrt(hd))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)
