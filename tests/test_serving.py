"""Serving with cluster-wide KV prefix-cache dedup."""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import ChunkingSpec, DedupCluster
from repro.serving import BatchedServer, KVBlockCache, ServeConfig


@pytest.fixture(scope="module")
def server():
    cfg = get_config("qwen2.5-32b").reduced()
    from repro.models import build_model

    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    cluster = DedupCluster.create(3, chunking=ChunkingSpec("fixed", 16 * 1024))
    return BatchedServer(m, params, cluster, ServeConfig(max_len=96, block_tokens=8))


def test_prefix_reuse_and_determinism(server):
    p = list(range(40, 72))
    r1 = server.handle(p, gen_tokens=4)
    r2 = server.handle(p + [9, 9], gen_tokens=4)
    r3 = server.handle(p, gen_tokens=4)
    assert r1["reused_tokens"] == 0
    assert r2["reused_tokens"] >= 32
    assert r3["reused_tokens"] == 24  # last block always recomputed
    assert r1["tokens"] == r3["tokens"], "cached-prefix decode must be deterministic"


def test_divergent_prefixes_do_not_cross_match(server):
    a = server.handle([1] * 32, gen_tokens=2)
    b = server.handle([2] * 32, gen_tokens=2)
    assert b["reused_tokens"] == 0


def test_chain_fingerprints_capture_position():
    cluster = DedupCluster.create(2, chunking=ChunkingSpec("fixed", 4096))
    kv = KVBlockCache(cluster, block_tokens=4)
    fps_a = kv.block_fps([1, 2, 3, 4, 5, 6, 7, 8])
    fps_b = kv.block_fps([5, 6, 7, 8, 1, 2, 3, 4])
    assert fps_a[0] != fps_b[1], "same tokens at different prefix => different identity"


def test_eviction_respects_pins_and_reclaims_space(server):
    kv = server.kv
    before_unique = server.kv.cluster.unique_bytes_stored()
    server.handle(list(range(100, 132)), gen_tokens=2)
    assert server.kv.cluster.unique_bytes_stored() > 0
    evicted = kv.evict(0)  # no pins held after handle() returns
    assert evicted > 0
    cl = kv.cluster
    cl.tick(20); cl.run_gc(); cl.tick(20); cl.run_gc()
    # evicted blocks' chunks reclaimed (other requests' blocks may remain)
    assert cl.unique_bytes_stored() <= before_unique + 1


def test_kv_identity_dedups_across_replicas():
    """Two serving replicas writing the same prefix block store it once."""
    import os

    cluster = DedupCluster.create(4, chunking=ChunkingSpec("fixed", 4096))
    kv1 = KVBlockCache(cluster, block_tokens=4)
    kv2 = KVBlockCache(cluster, block_tokens=4)
    payload = os.urandom(9000)
    fps1 = kv1.block_fps([1, 2, 3, 4])
    fps2 = kv2.block_fps([1, 2, 3, 4])
    assert fps1 == fps2
    kv1.put_blocks(fps1, [payload])
    kv2.put_blocks(fps2, [payload])   # idempotent dedup
    assert cluster.unique_bytes_stored() == 9000
    n, _ = kv2.match_prefix([1, 2, 3, 4, 9, 9, 9, 9])
    assert n == 4
