"""Asynchronous tagged consistency: crash windows, repair, GC safety."""

import os

import pytest

from repro.core import (
    ChunkingSpec,
    DedupCluster,
    TransactionAbort,
    WriteError,
)
from repro.core.dmshard import INVALID, VALID

CH = ChunkingSpec("fixed", 1024)


def mk(n=3, replicas=1):
    return DedupCluster.create(n, replicas=replicas, chunking=CH)


def test_flags_flip_asynchronously():
    c = mk()
    c.write_object("a", os.urandom(4096))
    invalid_now = sum(len(n.shard.invalid_fps()) for n in c.nodes.values())
    assert invalid_now == 4, "flags must still be INVALID right after the write"
    c.tick(2)
    assert sum(len(n.shard.invalid_fps()) for n in c.nodes.values()) == 0


def test_crash_before_flip_leaves_invalid_flags_then_repair_on_dup_write():
    c = mk()
    data = os.urandom(4096)
    c.write_object("x", data)        # flips still queued
    for n in c.nodes.values():
        n.crash()
    for n in c.nodes.values():
        n.restart()
    assert sum(n.cm.flips_lost_to_crash for n in c.nodes.values()) == 4
    assert sum(len(n.shard.invalid_fps()) for n in c.nodes.values()) == 4
    # duplicate write triggers the paper's consistency check -> repair
    c.write_object("y", data)
    assert sum(n.stats.repairs for n in c.nodes.values()) == 4
    assert sum(len(n.shard.invalid_fps()) for n in c.nodes.values()) == 0
    assert c.read_object("x") == data and c.read_object("y") == data


def test_read_path_repairs_invalid_flags():
    c = mk()
    data = os.urandom(2048)
    c.write_object("x", data)
    for n in c.nodes.values():
        n.crash(); n.restart()
    assert c.read_object("x") == data
    assert sum(len(n.shard.invalid_fps()) for n in c.nodes.values()) == 0


def test_aborted_txn_leaves_garbage_then_gc_collects():
    c = mk()
    def inj(event, ctx):
        if event == "before_chunk_op" and ctx["index"] == 3:
            raise TransactionAbort("fail")
    c.fault_injector = inj
    with pytest.raises(WriteError):
        c.write_object("bad", os.urandom(8192))
    c.fault_injector = None
    garbage = sum(len(n.shard.invalid_fps()) for n in c.nodes.values())
    assert garbage == 3, "3 stored chunks of the failed txn must be invalid"
    c.tick(20); c.run_gc()
    c.tick(20)
    removed = sum(len(v) for v in c.run_gc().values())
    assert removed == 3
    assert c.unique_bytes_stored() == 0


def test_gc_never_collects_referenced_chunks():
    c = mk()
    data = os.urandom(8192)
    c.write_object("keep", data)
    c.tick(2)
    for _ in range(5):
        c.tick(50)
        c.run_gc()
    assert c.read_object("keep") == data


def test_gc_cross_match_spares_rereferenced_chunks():
    """A fingerprint that goes invalid but is re-referenced before the GC
    threshold expires must be spared (the paper's cross-matching)."""
    c = mk()
    data = os.urandom(1024)
    c.write_object("a", data)
    c.tick(2)
    c.delete_object("a")               # refcount 0 -> tombstone (flag INVALID)
    c.run_gc()                         # phase 1: held set
    c.tick(5)
    c.write_object("b", data)          # re-reference repairs the entry
    c.tick(20)
    removed = sum(len(v) for v in c.run_gc().values())
    assert removed == 0
    spared = sum(n.gc.spared for n in c.nodes.values())
    assert spared == 1
    assert c.read_object("b") == data


def test_primary_crash_mid_txn_rolls_back_reachable_refs():
    c = mk(4)
    data = os.urandom(8192)
    c.write_object("base", data)
    c.tick(2)
    # now write a duplicate object but crash the primary before OMAP commit
    def inj(event, ctx):
        if event == "before_omap" and ctx["name"] == "dup":
            raise TransactionAbort("primary dies before OMAP write")
    c.fault_injector = inj
    with pytest.raises(WriteError):
        c.write_object("dup", data)
    c.fault_injector = None
    # rollback: refcounts back to 1 (only "base" references them)
    for node in c.nodes.values():
        for fp, e in node.shard.cit.items():
            assert e.refcount == 1
    assert c.read_object("base") == data


def test_flag_semantics_constants():
    assert INVALID == 0 and VALID == 1


def test_gc_crash_race_must_not_collect_committed_chunks():
    """Regression (found by hypothesis): write commits -> GC holds the
    still-invalid fps -> crash loses the async flips -> after the aging
    threshold the cross-match sees 'no change' and would delete LIVE data.
    The sweep must consistency-check referenced entries instead."""
    c = mk()
    data = os.urandom(2048)
    c.write_object("live", data)     # committed; flips queued
    c.run_gc()                        # phase 1 observes invalid fps
    for n in c.nodes.values():
        n.crash(); n.restart()        # flips lost forever
    c.tick(20)                        # age past threshold (no flips happen)
    removed = sum(len(v) for v in c.run_gc().values())
    assert removed == 0, "GC deleted committed, referenced chunks"
    assert c.read_object("live") == data
    # and the sweep repaired the flags via the consistency check
    assert sum(len(n.shard.invalid_fps()) for n in c.nodes.values()) == 0
    assert sum(n.gc.repaired for n in c.nodes.values()) == 2
