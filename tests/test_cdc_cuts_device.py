"""Device-resident CDC cut selection: the fused Pallas kernel (interpret
mode) and its jnp oracle must produce cut lists BYTE-IDENTICAL to the scalar
reference ``chunk_cdc_scalar`` for any stream and any ``ChunkingSpec`` —
including the ``hard = max(lo, start + max_size - 1)`` forced-cut edge and
stream tails shorter than ``min_size`` — and the fused per-chunk
fingerprints must match the host-built row oracle.

Two layers: a seeded sweep that always runs (no external deps), and a
hypothesis property suite when hypothesis is installed (CI installs it).
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro.core.chunking import (
    GEAR_TABLE,
    ChunkingSpec,
    cdc_mask,
    chunk_cdc,
    chunk_cdc_scalar,
)
from repro.core.fingerprint import fingerprint_many
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.kernels.cdc import cdc_cut_masks_pallas

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI always has hypothesis
    HAVE_HYPOTHESIS = False

_GEAR = jnp.asarray(np.array(GEAR_TABLE, dtype=np.uint32))


def _scalar_cuts(data: bytes, spec: ChunkingSpec) -> np.ndarray:
    """Inclusive chunk-end positions (tail excluded), via the scalar loop
    itself (chunk lengths alone cannot distinguish a final cut from a tail)."""
    cuts = []
    spec = spec.normalized()
    mask = cdc_mask(spec.chunk_size)
    start, i, n = 0, spec.min_size, len(data)
    from repro.core.chunking import window_hash_at

    while i < n:
        if (window_hash_at(data, i) & mask) == 0 or (i - start + 1) >= spec.max_size:
            cuts.append(i)
            start = i + 1
            i = start + spec.min_size
        else:
            i += 1
    return np.asarray(cuts, dtype=np.int64)


def _device_cuts(data: bytes, spec: ChunkingSpec, *, interpret: bool, block_len=512):
    spec = spec.normalized()
    mask = cdc_mask(spec.chunk_size)
    tv = jnp.take(_GEAR, jnp.asarray(np.frombuffer(data, np.uint8)).astype(jnp.int32))
    if interpret:
        m = cdc_cut_masks_pallas(
            [tv], mask=mask, min_size=spec.min_size, max_size=spec.max_size,
            interpret=True, block_len=block_len,
        )[0]
    else:
        cand = (ref.cdc_hashes(tv) & jnp.uint32(mask)) == 0
        m = ref.cdc_cut_mask(cand, len(data), spec.min_size, spec.max_size)
    return np.flatnonzero(np.asarray(m))


def _host_fp_rows(chunks: list[bytes], max_size: int) -> np.ndarray:
    """Numpy oracle for the fused fingerprint row contract (fp_row_words)."""
    row_words, width = kops.fp_row_words(max_size)
    rows = np.zeros((len(chunks), width), np.uint32)
    for i, c in enumerate(chunks):
        b = c + b"\0" * (row_words * 4 - len(c))
        rows[i, :row_words] = np.frombuffer(b, "<u4")
        rows[i, row_words] = len(c)
    return rows


def _check_spec(data: bytes, spec: ChunkingSpec, *, interpret: bool) -> None:
    exp = _scalar_cuts(data, spec)
    got = _device_cuts(data, spec, interpret=interpret)
    np.testing.assert_array_equal(got, exp)


# --------------------------------------------------------------- seeded sweep

SWEEP = [
    # (n, target, min_size, max_size) — 0 means "let normalized() pick"
    (3000, 256, 64, 1024),
    (4096, 64, 1, 97),
    (100, 1024, 60, 4096),      # whole stream shorter than min_size window
    (1, 16, 1, 8),
    (777, 32, 31, 33),
    (2048, 128, 100, 101),      # max_size == min_size + 1: hard-cut dominated
    (1500, 64, 50, 50),         # max_size == min_size: hard = lo always
    (5000, 512, 0, 0),
]


@pytest.mark.parametrize("n,target,mn,mx", SWEEP)
def test_device_cuts_match_scalar_oracle(n, target, mn, mx):
    rng = np.random.default_rng(n * 31 + target)
    data = rng.integers(0, 256, size=n, dtype=np.uint8).tobytes()
    spec = ChunkingSpec("cdc", target, mn, mx)
    _check_spec(data, spec, interpret=False)
    _check_spec(data, spec, interpret=True)


def test_device_cuts_low_entropy_forced_cuts():
    """Constant bytes have (almost) no candidates: every cut is a max-size
    hard cut, including the hard = max(lo, start+max_size-1) lower clamp."""
    data = b"\x42" * 3000
    spec = ChunkingSpec("cdc", 128, 100, 300)
    assert len(_scalar_cuts(data, spec)) > 0
    _check_spec(data, spec, interpret=False)
    _check_spec(data, spec, interpret=True)


def test_device_cuts_tail_shorter_than_min():
    """Stream whose last chunk is a tail < min_size (never emitted as a cut)."""
    rng = np.random.default_rng(9)
    spec = ChunkingSpec("cdc", 64, 48, 256)
    for extra in (1, 7, 47):
        base = rng.integers(0, 256, size=1024, dtype=np.uint8).tobytes()
        cuts = _scalar_cuts(base, spec)
        if cuts.size == 0:
            continue
        data = base[: int(cuts[-1]) + 1 + extra]  # tail of exactly `extra` B
        _check_spec(data, spec, interpret=False)
        _check_spec(data, spec, interpret=True)


def test_chunk_cdc_device_backend_bit_identical():
    rng = np.random.default_rng(17)
    data = rng.integers(0, 256, size=40 * 1024, dtype=np.uint8).tobytes()
    spec = ChunkingSpec("cdc", 1024)
    dev = list(chunk_cdc(data, spec, backend="device"))
    assert dev == list(chunk_cdc_scalar(data, spec))
    assert b"".join(dev) == data
    # identical bytes => identical canonical fingerprints
    assert fingerprint_many(dev) == fingerprint_many(chunk_cdc_scalar(data, spec))


@pytest.mark.parametrize("interpret", [False, True])
def test_fused_fingerprints_match_host_rows(interpret):
    rng = np.random.default_rng(23)
    spec = ChunkingSpec("cdc", 256, 64, 700)
    streams = [rng.integers(0, 256, size=n, dtype=np.uint8) for n in (3000, 64, 1, 517)]
    res = kops.cdc_cut_and_fingerprint_many(
        [jnp.asarray(s) for s in streams],
        mask=cdc_mask(spec.chunk_size),
        min_size=spec.min_size, max_size=spec.max_size,
        use_pallas=False, interpret=interpret, block_len=512,
    )
    for s, (cutpos, n_cuts, fps, n_chunks) in zip(streams, res):
        chunks = list(chunk_cdc_scalar(s.tobytes(), spec))
        assert int(n_chunks) == len(chunks)
        ends = np.cumsum([len(c) for c in chunks]) - 1
        np.testing.assert_array_equal(np.asarray(cutpos)[: int(n_cuts)], ends[: int(n_cuts)])
        exp = np.asarray(ref.fingerprint_chunks(jnp.asarray(_host_fp_rows(chunks, spec.max_size))))
        np.testing.assert_array_equal(np.asarray(fps)[: int(n_chunks)], exp)


def test_fused_one_launch_per_wave():
    rng = np.random.default_rng(29)
    streams = [jnp.asarray(rng.integers(0, 256, size=n, dtype=np.uint8)) for n in (2048, 999)]
    before = kops.launch_snapshot()
    kops.cdc_cut_and_fingerprint_many(
        streams, mask=255, min_size=64, max_size=512, use_pallas=False
    )
    after = kops.launch_snapshot()
    assert after["cdc"] - before["cdc"] == 1
    assert after["fingerprint"] - before["fingerprint"] == 1


def test_fused_empty_wave_no_launch():
    before = kops.launch_snapshot()
    res = kops.cdc_cut_and_fingerprint_many(
        [jnp.zeros((0,), jnp.uint8)], mask=255, min_size=64, max_size=512,
        use_pallas=False,
    )
    assert kops.launch_snapshot() == before
    assert int(res[0][3]) == 0


# ----------------------------------------------------------------- hypothesis


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(
        data=st.binary(min_size=0, max_size=2500),
        target=st.sampled_from([16, 32, 64, 256, 1024]),
        min_size=st.integers(1, 80),
        extra=st.integers(0, 400),
        entropy=st.sampled_from(["random", "zero", "repeat8"]),
    )
    def test_property_device_cuts_byte_identical(data, target, min_size, extra, entropy):
        if entropy == "zero":
            data = b"\x00" * len(data)
        elif entropy == "repeat8":
            data = (data[:8] or b"\x07") * (len(data) // 8 + 1)
        spec = ChunkingSpec("cdc", target, min_size, max(min_size, min_size + extra))
        if not data:
            assert list(chunk_cdc_scalar(data, spec)) == []
            return
        _check_spec(data, spec, interpret=False)
        _check_spec(data, spec, interpret=True)

    @settings(max_examples=15, deadline=None)
    @given(
        sizes=st.lists(st.integers(1, 1200), min_size=1, max_size=5),
        seed=st.integers(0, 2**16),
        target=st.sampled_from([64, 256]),
    )
    def test_property_fused_wave_matches_scalar(sizes, seed, target):
        """Whole-wave fusion: every stream's cuts and fingerprints must match
        the per-stream scalar oracle — no cross-stream hash or carry
        leakage."""
        rng = np.random.default_rng(seed)
        spec = ChunkingSpec("cdc", target).normalized()
        streams = [rng.integers(0, 256, size=n, dtype=np.uint8) for n in sizes]
        res = kops.cdc_cut_and_fingerprint_many(
            [jnp.asarray(s) for s in streams],
            mask=cdc_mask(spec.chunk_size),
            min_size=spec.min_size, max_size=spec.max_size,
            use_pallas=False, interpret=True, block_len=256,
        )
        for s, (cutpos, n_cuts, fps, n_chunks) in zip(streams, res):
            chunks = list(chunk_cdc_scalar(s.tobytes(), spec))
            assert int(n_chunks) == len(chunks)
            ends = np.cumsum([len(c) for c in chunks]) - 1
            np.testing.assert_array_equal(
                np.asarray(cutpos)[: int(n_cuts)], ends[: int(n_cuts)]
            )
            exp = np.asarray(
                ref.fingerprint_chunks(jnp.asarray(_host_fp_rows(chunks, spec.max_size)))
            )
            np.testing.assert_array_equal(np.asarray(fps)[: int(n_chunks)], exp)
