"""Scheduler determinism and clock-skew chaos schedules (core/simclock.py).

The discrete-event core's contract is reproducibility: same seed, same
event order, same stats, same final state — and a *different* seed is a
different legal interleaving, not a different outcome after recovery.
The skew tests prove the tombstone-reap guard (ROADMAP item 4) is
load-bearing: a fast local clock reaps a tombstone before its true age
passes the GC horizon, and a crashed replica rejoining with the old
live entry resurrects the deleted object — unless every node widens its
reap horizon by the skew bound.
"""

import numpy as np
import pytest

from repro.core import (
    ChunkingSpec,
    DedupCluster,
    ReadError,
    Scheduler,
    SimClock,
    name_fp,
)
from repro.core.placement import place

CH = ChunkingSpec("fixed", 2048)


# --------------------------------------------------------------- SimClock
def test_simclock_is_monotonic_and_skew_bounded():
    clk = SimClock(offsets={"oss0": 5, "oss1": -3})
    assert clk.advance(4) == 4
    assert clk.node_now("oss0") == 9
    assert clk.node_now("oss1") == 1
    assert clk.node_now("oss2") == 4       # no offset -> shared axis
    assert clk.max_skew == 5
    with pytest.raises(ValueError):
        clk.advance(-1)


# -------------------------------------------------------- actor mechanics
def test_scheduler_runs_oneshot_actors_and_collects_results():
    c = DedupCluster.create(2, replicas=1, chunking=CH)
    sched = Scheduler(c, seed=1)
    trace = []

    def actor(tag, delays):
        for d in delays:
            trace.append((c.now, tag))
            yield d
        return tag

    sched.spawn(actor("a", [2, 2]), name="a")
    sched.spawn(actor("b", [1, 1, 1]), name="b")
    results = sched.run()
    assert results == {"a": "a", "b": "b"}
    # every resume happened at the tick the actor asked for
    assert [t for t, tag in trace if tag == "a"] == [0, 2]
    assert [t for t, tag in trace if tag == "b"] == [0, 1, 2]
    assert sched.errors == {}


def test_recurring_actor_interleaves_but_does_not_keep_run_alive():
    c = DedupCluster.create(2, replicas=1, chunking=CH)
    sched = Scheduler(c, seed=1)
    fires = []

    def oneshot():
        for _ in range(3):
            yield 4

    sched.spawn(oneshot(), name="work")
    sched.every(3, lambda: fires.append(c.now), name="gc")
    sched.run()
    # the recurring actor fired while the one-shot was alive, then stopped
    assert fires and all(t <= c.now for t in fires)
    assert fires == sorted(fires)
    n_at_quiesce = len(fires)
    sched.run()  # nothing one-shot left: returns without spinning on "gc"
    assert len(fires) == n_at_quiesce


def test_duplicate_actor_name_rejected():
    c = DedupCluster.create(2, replicas=1, chunking=CH)
    sched = Scheduler(c, seed=0)
    sched.spawn(iter(()), name="a")
    with pytest.raises(ValueError):
        sched.spawn(iter(()), name="a")


def test_run_until_leaves_clock_at_target():
    c = DedupCluster.create(2, replicas=1, chunking=CH)
    sched = Scheduler(c, seed=0)
    sched.run_until(17)
    assert c.now == 17 and sched.clock.now == 17


# ------------------------------------------------------------ determinism
def _seeded_run(sched_seed, spec_seed=7):
    from repro.core import WorkloadSpec, run_workload

    c = DedupCluster.create(4, replicas=2, chunking=CH)
    sched = Scheduler(c, seed=sched_seed)
    spec = WorkloadSpec(
        clients=6, objects=16, ops_per_client=6, seed=spec_seed,
        bulk_first=2, wave_bytes=8192,
    )
    rep = run_workload(c, spec, scheduler=sched)
    return c, sched, rep


def test_same_seed_same_event_order_stats_and_state():
    c1, s1, r1 = _seeded_run(3)
    c2, s2, r2 = _seeded_run(3)
    assert s1.event_log == s2.event_log
    assert r1 == r2
    assert c1.stats.snapshot() == c2.stats.snapshot()
    omap1 = {
        nid: {n: (e.version, e.deleted) for n, e in nd.shard.omap.items()}
        for nid, nd in c1.nodes.items()
    }
    omap2 = {
        nid: {n: (e.version, e.deleted) for n, e in nd.shard.omap.items()}
        for nid, nd in c2.nodes.items()
    }
    assert omap1 == omap2


def test_different_scheduler_seed_is_a_different_interleaving():
    """Same workload spec, different tie-break seed: events at shared
    ticks pop in a different order (the seeded tiebreak is live), while
    each run stays internally consistent (own replay oracle matches —
    covered in tests/test_workload.py)."""
    _, s1, _ = _seeded_run(3)
    _, s2, _ = _seeded_run(4)
    assert [e[:2] for e in s1.event_log] != [e[:2] for e in s2.event_log]


# ------------------------------------------------------------- clock skew
def _skew_schedule(guard: bool):
    """The reap-guard chaos schedule: replica B crashes holding live v1,
    the delete lands a tombstone on A only, then A's clock steps forward
    by ``skew`` (an NTP jump after stamping). At true age
    ``horizon - skew + 1`` A's *local* clock says the horizon passed."""
    c = DedupCluster.create(3, replicas=2, chunking=CH)
    data = np.random.default_rng(4).bytes(4096)
    c.write_object("x", data)
    c.tick(2)
    a, b = place(name_fp("x"), c.cmap)[:2]
    c.crash_node(b)
    assert c.delete_object("x")
    horizon = c.nodes[a].gc.tombstone_horizon
    skew = 10
    assert c.set_clock_skew({a: skew}, guard=guard) == skew
    c.tick(horizon - skew + 1)      # true age < horizon; A perceives >= horizon
    early = c.recover().tombstones_reaped
    c.restart_node(b)
    rejoin = c.recover()
    live = {
        nid: e
        for nid, nd in c.nodes.items()
        if (e := nd.shard.omap.get("x")) is not None and not e.deleted
    }
    return c, skew, horizon, early, rejoin, live


def test_unguarded_fast_clock_reaps_early_and_resurrects():
    """Without the guard the fast clock nominates the tombstone before
    its true age reaches the horizon; full-ack is satisfied (the crashed
    replica isn't a live target), the tombstone dies, and the rejoining
    replica's stale live v1 — which the tombstone existed to beat —
    repairs back onto the placement targets: the deleted object
    resurrects, with its chunk refs already released to GC."""
    c, skew, horizon, early, rejoin, live = _skew_schedule(guard=False)
    assert early == 1
    assert live, "expected the stale live entry to resurrect"
    assert all(e.version == 1 for e in live.values())
    with pytest.raises(ReadError):
        c.read_object("x")          # bytes already reclaimed: data loss


def test_skew_guard_blocks_early_reap_and_keeps_delete():
    """With the guard every node widens its reap horizon by the skew
    bound, so the fast clock cannot nominate early; the rejoining
    replica's stale v1 loses to the still-alive tombstone v2 and the
    name stays deleted. The guard only *delays* reaping: once true age
    passes ``horizon + skew`` the tombstone is reaped on both replicas."""
    c, skew, horizon, early, rejoin, live = _skew_schedule(guard=True)
    assert early == 0
    assert not live, "guarded schedule must not resurrect the delete"
    with pytest.raises(ReadError):
        c.read_object("x")
    c.tick(skew + horizon)          # now past horizon + guard on every clock
    assert c.recover().tombstones_reaped == 2
    assert all("x" not in nd.shard.omap for nd in c.nodes.values())


def test_scheduler_mirrors_cluster_skew():
    c = DedupCluster.create(3, replicas=2, chunking=CH)
    sched = Scheduler(c, seed=0)
    assert sched.set_clock_skew({"oss0": 7, "oss1": -2}) == 7
    assert sched.clock.offsets == {"oss0": 7, "oss1": -2}
    assert c.nodes["oss0"].clock_offset == 7
    assert c.nodes["oss0"].skew_guard == 7      # bound, not own offset
    assert c.nodes["oss2"].skew_guard == 7
    sched.run_until(5)
    assert sched.clock.node_now("oss0") == 12
