"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle vs
host reference."""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro.core.chunking import GEAR_TABLE, window_hash_at
from repro.kernels import ops, ref
from repro.kernels.cdc import cdc_hashes_pallas
from repro.kernels.fingerprint import fingerprint_chunks_pallas

RNG = np.random.default_rng(42)


@pytest.mark.parametrize(
    "shape",
    [(1, 128), (2, 129), (5, 511), (8, 512), (13, 1000), (256, 512), (300, 700), (257, 513)],
)
def test_fingerprint_pallas_matches_ref(shape):
    x = jnp.asarray(RNG.integers(0, 2**32, size=shape, dtype=np.uint32))
    r = np.asarray(ref.fingerprint_chunks(x))
    p = np.asarray(fingerprint_chunks_pallas(x, interpret=True))
    np.testing.assert_array_equal(r, p)


@pytest.mark.parametrize("tc,tw", [(8, 128), (64, 256), (256, 512)])
def test_fingerprint_pallas_tile_invariance(tc, tw):
    x = jnp.asarray(RNG.integers(0, 2**32, size=(70, 600), dtype=np.uint32))
    r = np.asarray(ref.fingerprint_chunks(x))
    p = np.asarray(fingerprint_chunks_pallas(x, interpret=True, tile_chunks=tc, tile_words=tw))
    np.testing.assert_array_equal(r, p)


def test_fingerprint_rows_independent():
    x = jnp.asarray(RNG.integers(0, 2**32, size=(4, 256), dtype=np.uint32))
    full = np.asarray(ref.fingerprint_chunks(x))
    for i in range(4):
        row = np.asarray(ref.fingerprint_chunks(x[i : i + 1]))
        np.testing.assert_array_equal(full[i], row[0])


def test_fingerprint_avalanche():
    """Single-bit flips must change most output bits (mix quality)."""
    x = jnp.asarray(RNG.integers(0, 2**32, size=(1, 256), dtype=np.uint32))
    base = np.asarray(ref.fingerprint_chunks(x))[0]
    flipped_bits = []
    for trial in range(16):
        xi = np.array(x)
        xi[0, trial * 16] ^= 1 << (trial % 32)
        out = np.asarray(ref.fingerprint_chunks(jnp.asarray(xi)))[0]
        diff = np.bitwise_xor(base, out)
        flipped_bits.append(sum(bin(int(w)).count("1") for w in diff))
    assert np.mean(flipped_bits) > 40, np.mean(flipped_bits)  # ~64 expected of 128


def test_fingerprint_no_collisions_bulk():
    x = jnp.asarray(RNG.integers(0, 2**32, size=(2000, 64), dtype=np.uint32))
    fps = np.asarray(ref.fingerprint_chunks(x))
    assert len({tuple(r) for r in fps}) == 2000


@pytest.mark.parametrize("n", [33, 256, 2048, 5000, 16384])
def test_cdc_pallas_matches_ref_and_host(n):
    data = RNG.integers(0, 256, size=n, dtype=np.uint8)
    tv = jnp.take(jnp.asarray(np.array(GEAR_TABLE, dtype=np.uint32)),
                  jnp.asarray(data).astype(jnp.int32))
    r = np.asarray(ref.cdc_hashes(tv))
    p = np.asarray(cdc_hashes_pallas(tv, interpret=True))
    np.testing.assert_array_equal(r, p)
    b = bytes(data)
    for i in [0, 1, 31, 32, n // 3, n - 1]:
        assert int(r[i]) == window_hash_at(b, i)


def test_cdc_boundary_mask():
    data = RNG.integers(0, 256, size=4096, dtype=np.uint8)
    mask = (1 << 8) - 1
    bounds = np.asarray(ops.cdc_boundaries(jnp.asarray(data), mask, use_pallas=False))
    frac = bounds.mean()
    assert 1 / 1024 < frac < 1 / 64  # ~1/256 expected


@pytest.mark.parametrize(
    "dtype,shape",
    [
        ("uint8", (7,)), ("uint8", (128,)), ("uint8", (3, 5)),
        ("bfloat16", (33,)), ("bfloat16", (16, 16)),
        ("float16", (9,)), ("float16", (64,)),
        ("float32", (1,)), ("float32", (17, 3)),
        ("float64", (5,)), ("float64", (8, 8)),
        ("int64", (3,)), ("int64", (31,)),
        ("bool", (13,)),
    ],
)
def test_tensor_to_u32_matches_numpy_bytes(dtype, shape):
    """tensor_to_u32 must pack the tensor's raw little-endian bytes into
    uint32 words — exactly np.frombuffer(arr.tobytes() + pad, '<u4') — for
    every dtype, including the wide (f64/i64) and sub-word (u8/bool) paths."""
    with jax.experimental.enable_x64(True):
        n = int(np.prod(shape))
        if dtype == "bool":
            host = (RNG.integers(0, 2, size=shape) > 0)
            t = jnp.asarray(host)
        elif dtype == "bfloat16":
            host16 = RNG.integers(0, 2**16, size=shape, dtype=np.uint16)
            t = jnp.asarray(host16).view(jnp.bfloat16)
            host = np.asarray(jax.device_get(t))
        elif np.issubdtype(np.dtype(dtype), np.integer):
            info = np.iinfo(dtype)
            host = RNG.integers(info.min, info.max, size=shape, dtype=dtype)
            t = jnp.asarray(host)
        else:
            host = RNG.standard_normal(n).reshape(shape).astype(dtype)
            t = jnp.asarray(host)
        raw = (host.astype(np.uint8) if dtype == "bool" else host).tobytes()
        padded = raw + b"\0" * ((-len(raw)) % 4)
        exp = np.frombuffer(padded, "<u4")
        got = np.asarray(jax.device_get(ops.tensor_to_u32(t)))
        np.testing.assert_array_equal(got, exp)
        # and the u8 view must be the raw bytes themselves (unpadded)
        got8 = np.asarray(jax.device_get(ops.tensor_to_u8(t)))
        np.testing.assert_array_equal(got8, np.frombuffer(raw, np.uint8))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32, jnp.int8, jnp.float16])
def test_tensor_fingerprint_dtypes(dtype):
    t = jnp.asarray(RNG.standard_normal((32, 64)) * 10).astype(dtype)
    fps = ops.fingerprint_tensor_chunks(t, chunk_bytes=2048, use_pallas=False)
    fps2 = ops.fingerprint_tensor_chunks(t, chunk_bytes=2048, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(fps), np.asarray(fps2))
    # perturb one element -> some fingerprint changes
    t2 = t.at[3, 5].set(t[3, 5] + jnp.asarray(1, dtype))
    fps3 = ops.fingerprint_tensor_chunks(t2, chunk_bytes=2048, use_pallas=False)
    assert not np.array_equal(np.asarray(fps), np.asarray(fps3))


def test_tensor_fingerprint_pallas_path_matches_ref_path():
    t = jnp.asarray(RNG.standard_normal((64, 128)), dtype=jnp.float32)
    a = ops.fingerprint_tensor_chunks(t, chunk_bytes=4096, use_pallas=False)
    # use_pallas=True on CPU -> falls to pallas interpret through jit? The
    # wrapper compiles pallas only on TPU; emulate via direct interpret call:
    from repro.kernels.ops import tensor_to_u32
    flat = tensor_to_u32(t)
    words = jnp.pad(flat, (0, (-flat.shape[0]) % 1024)).reshape(-1, 1024)
    b = fingerprint_chunks_pallas(words, interpret=True)
    r = ref.fingerprint_chunks(words)
    np.testing.assert_array_equal(np.asarray(b), np.asarray(r))
    assert np.asarray(a).shape[1] == 4
