"""Storage rebalancing & elasticity: the paper's Fig 1(b) problem solved by
content placement — chunks move, metadata locations never do."""

import os

import pytest

from repro.core import ChunkingSpec, DedupCluster
from repro.core.placement import place

CH = ChunkingSpec("fixed", 1024)


def _fill(c, n_objects=12, size=8192, seed=0):
    import numpy as np

    rng = np.random.default_rng(seed)
    objs = {}
    for i in range(n_objects):
        data = rng.bytes(size)
        name = f"obj{i}"
        c.write_object(name, data)
        objs[name] = data
    c.tick(2)
    return objs


def test_add_node_preserves_all_reads():
    c = DedupCluster.create(4, chunking=CH)
    objs = _fill(c)
    c.add_node()
    for name, data in objs.items():
        assert c.read_object(name) == data


def test_remove_node_preserves_all_reads():
    c = DedupCluster.create(5, chunking=CH)
    objs = _fill(c)
    c.remove_node("oss4")
    for name, data in objs.items():
        assert c.read_object(name) == data


def test_movement_is_minimal():
    """HRW: adding 1 node to N=7 should move ~1/8 of chunks, not reshuffle."""
    c = DedupCluster.create(7, chunking=CH)
    _fill(c, n_objects=40, size=4096)
    total_chunks = sum(len(n.chunk_store) for n in c.nodes.values())
    c.add_node()
    frac = c.stats.rebalance_chunks_moved / total_chunks
    assert frac < 0.30, f"moved {frac:.0%}, expected ~1/8"


def test_no_dedup_metadata_location_updates_needed():
    """After rebalance, every CIT entry is findable purely via place(fp, map)
    — the paper's claim that dedup metadata needs no location rewrite."""
    c = DedupCluster.create(4, chunking=CH)
    _fill(c)
    c.add_node()
    for nid, node in c.nodes.items():
        for fp in node.shard.cit:
            assert nid in place(fp, c.cmap), (
                f"CIT entry {fp} on {nid} is off-placement after rebalance"
            )
        for fp in node.chunk_store:
            assert nid in place(fp, c.cmap)


def test_chunk_distribution_rebalances():
    c = DedupCluster.create(3, chunking=CH)
    _fill(c, n_objects=60, size=4096)
    c.add_node()
    dist = c.chunk_distribution()
    assert dist["oss3"] > 0, "new node must receive chunks"
    avg = sum(dist.values()) / len(dist)
    assert all(v > 0.3 * avg for v in dist.values()), dist


def test_dedup_survives_rebalance():
    c = DedupCluster.create(3, chunking=CH)
    data = os.urandom(8192)
    c.write_object("a", data)
    c.tick(2)
    c.add_node()
    c.write_object("b", data)      # must still dedup against moved chunks
    assert c.unique_bytes_stored() == 8192
    assert c.read_object("b") == data


def test_scrub_restores_replication_after_permanent_loss():
    c = DedupCluster.create(4, replicas=2, chunking=CH)
    objs = _fill(c)
    victim = list(c.nodes)[0]
    c.nodes[victim].chunk_store.clear()        # simulate disk loss
    c.nodes[victim].shard.cit.clear()
    restored = c.scrub()
    assert restored > 0
    for name, data in objs.items():
        assert c.read_object(name) == data


def test_weighted_elastic_scaling():
    c = DedupCluster.create(4, chunking=CH)
    _fill(c, n_objects=40)
    c.set_map(c.cmap.with_node("big", weight=3.0))
    dist = c.chunk_distribution()
    avg_small = sum(v for k, v in dist.items() if k != "big") / 4
    assert dist["big"] > 1.5 * avg_small, dist
