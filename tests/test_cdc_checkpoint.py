"""CDC-chunked checkpointing: dedup robust to byte-shifts (insertions).

Fixed-size chunking loses all dedup after a small prefix insertion shifts
every boundary; content-defined chunking re-synchronizes — this matters for
checkpoint streams whose serialization layout can shift (e.g. a metadata
header that grows by a few bytes between framework versions)."""

import os

from repro.core import ChunkingSpec, DedupCluster


def _savings_after_shift(kind: str) -> float:
    spec = ChunkingSpec(kind, 2048)
    c = DedupCluster.create(4, chunking=spec)
    # 96 KiB is ~48 CDC chunks — plenty to show re-synchronization while
    # keeping the fixture small (the chunker itself is vectorized now).
    body = os.urandom(96 * 1024)
    c.write_object("v1", b"HDR1" + body)
    c.write_object("v2", b"HEADER-GREW-BY-SOME-BYTES" + body)
    return c.space_savings()


def test_cdc_survives_insertion_fixed_does_not():
    fixed = _savings_after_shift("fixed")
    cdc = _savings_after_shift("cdc")
    assert fixed < 0.05, f"fixed-size chunking should lose dedup, got {fixed:.2f}"
    assert cdc > 0.35, f"CDC should recover dedup past the shift, got {cdc:.2f}"


def test_cdc_chunk_boundaries_deterministic():
    from repro.core.chunking import chunk_object

    spec = ChunkingSpec("cdc", 1024)
    data = os.urandom(32 * 1024)
    a = chunk_object(data, spec)
    b = chunk_object(data, spec)
    assert [len(x) for x in a] == [len(x) for x in b]
    assert b"".join(a) == data
