"""CDC-chunked checkpointing: dedup robust to byte-shifts (insertions).

Fixed-size chunking loses all dedup after a small prefix insertion shifts
every boundary; content-defined chunking re-synchronizes — this matters for
checkpoint streams whose serialization layout can shift (e.g. a metadata
header that grows by a few bytes between framework versions)."""

import os

import pytest

from repro.core import ChunkingSpec, DedupCluster


def _savings_after_shift(kind: str) -> float:
    spec = ChunkingSpec(kind, 2048)
    c = DedupCluster.create(4, chunking=spec)
    # 96 KiB is ~48 CDC chunks — plenty to show re-synchronization while
    # keeping the fixture small (the chunker itself is vectorized now).
    body = os.urandom(96 * 1024)
    c.write_object("v1", b"HDR1" + body)
    c.write_object("v2", b"HEADER-GREW-BY-SOME-BYTES" + body)
    return c.space_savings()


def test_cdc_survives_insertion_fixed_does_not():
    fixed = _savings_after_shift("fixed")
    cdc = _savings_after_shift("cdc")
    assert fixed < 0.05, f"fixed-size chunking should lose dedup, got {fixed:.2f}"
    assert cdc > 0.35, f"CDC should recover dedup past the shift, got {cdc:.2f}"


def test_cdc_chunk_boundaries_deterministic():
    from repro.core.chunking import chunk_object

    spec = ChunkingSpec("cdc", 1024)
    data = os.urandom(32 * 1024)
    a = chunk_object(data, spec)
    b = chunk_object(data, spec)
    assert [len(x) for x in a] == [len(x) for x in b]
    assert b"".join(a) == data


def test_checkpointer_one_launch_pair_per_save():
    """The fused device pipeline must do exactly ONE CDC launch + ONE
    fingerprint launch per save wave, no matter how many leaves the pytree
    has — and the counters must surface in DedupCheckpointer.stats."""
    pytest.importorskip("jax")
    import jax.numpy as jnp

    from repro.checkpoint import CheckpointConfig, DedupCheckpointer

    cluster = DedupCluster.create(3, chunking=ChunkingSpec("fixed", 16 * 1024))
    ckpt = DedupCheckpointer(
        cluster, CheckpointConfig(fp_chunk_bytes=4096, device_cdc=True)
    )
    tree = {
        "w": jnp.arange(12_000, dtype=jnp.float32),
        "b": jnp.ones((257,), jnp.bfloat16),
        "step": 3,  # non-array leaf: must not add launches
        "emb": jnp.arange(5_000, dtype=jnp.int32),
    }
    assert ckpt.stats["cdc_launches"] == 0 and ckpt.stats["fp_launches"] == 0
    ckpt.save("s1", tree)
    assert ckpt.stats["cdc_launches"] == 1
    assert ckpt.stats["fp_launches"] == 1
    # second save of an identical tree: one more launch pair, all array
    # leaves ref-only
    ckpt.save("s2", tree)
    assert ckpt.stats["cdc_launches"] == 2
    assert ckpt.stats["fp_launches"] == 2
    assert ckpt.stats["leaves_ref_only"] == 3
    # legacy fixed-size route still books exactly one fingerprint launch
    ckpt2 = DedupCheckpointer(
        cluster, CheckpointConfig(fp_chunk_bytes=4096, device_cdc=False)
    )
    ckpt2.save("s3", tree)
    assert ckpt2.stats["cdc_launches"] == 0
    assert ckpt2.stats["fp_launches"] == 1
