import os
import sys

# smoke tests must see exactly 1 CPU device (the dry-run sets 512 itself,
# in its own process) — so no XLA_FLAGS here, per the launcher contract.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def assert_seen_window_margin(
    cluster, capacity: int = 1024, fraction: float = 0.25
) -> float:
    """Measured-margin seen-window pressure check for the chaos suites.

    Eviction pressure must be zero (an evicted id re-opens the double-apply
    window a late duplicate exploits), AND the peak occupancy must stay
    under ``fraction`` of the window's ``capacity`` — a measured headroom
    claim, not just "nothing fell out": a schedule that filled the window
    to 99% would still pass a zero-eviction assert while one extra
    in-flight message away from silent re-application.

    Returns the measured margin (peak / capacity) so callers can report
    it in their failure messages or print it under ``-s``.
    """
    stats = cluster.stats
    assert stats.seen_evictions == 0, (
        f"seen-window evicted {stats.seen_evictions} ids — in-flight depth "
        f"exceeded the {capacity}-id bound; late duplicates may re-apply"
    )
    high = stats.seen_high_water
    budget = int(capacity * fraction)
    assert high <= budget, (
        f"seen-window peak occupancy {high} exceeds the stated margin "
        f"{budget} ({fraction:.0%} of {capacity}): the schedule is "
        f"{high / capacity:.1%} into the window, too close to eviction"
    )
    return high / capacity
