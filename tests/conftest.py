import os
import sys

# smoke tests must see exactly 1 CPU device (the dry-run sets 512 itself,
# in its own process) — so no XLA_FLAGS here, per the launcher contract.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
