import os
import sys

# smoke tests must see exactly 1 CPU device (the dry-run sets 512 itself,
# in its own process) — so no XLA_FLAGS here, per the launcher contract.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def assert_seen_window_margin(cluster, capacity: int = 1024) -> float:
    """Measured-margin seen-window pressure check for the chaos suites.

    Eviction pressure must be zero: an evicted id re-opens the double-apply
    window a late duplicate exploits. Headroom itself is no longer asserted
    against a fixed fraction here — the old 25%-of-capacity margin was a
    guess, and the sizing study in ``bench_multi_tenant`` (benchmarks/
    write_path_bench.py) now *measures* peak occupancy vs in-flight depth
    and pins it as tolerance-0 bench-gate columns instead. A hard-coded
    fraction in the test suite would either shadow that gate or drift from
    it; the suite keeps only the correctness claim (zero evictions) and
    returns the measured margin so callers can report it under ``-s``.
    """
    stats = cluster.stats
    assert stats.seen_evictions == 0, (
        f"seen-window evicted {stats.seen_evictions} ids — in-flight depth "
        f"exceeded the {capacity}-id bound; late duplicates may re-apply"
    )
    return stats.seen_high_water / capacity
