"""End-to-end system behaviour: train -> checkpoint -> node failure ->
elastic rescale -> restore -> resume -> serve. The full lifecycle the
framework must survive on a real cluster, exercised on reduced configs."""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro.checkpoint import DedupCheckpointer
from repro.configs import get_config
from repro.core import ChunkingSpec, DedupCluster
from repro.data import SyntheticLMData
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.train import TrainConfig, train_loop
from repro.train.loop import init_train_state


def test_full_lifecycle():
    cfg = get_config("qwen2.5-32b").reduced()
    model = build_model(cfg)
    data = SyntheticLMData(vocab=cfg.vocab, seq_len=32, global_batch=4, seed=7)
    cluster = DedupCluster.create(4, replicas=2, chunking=ChunkingSpec("fixed", 128 * 1024))
    ck = DedupCheckpointer(cluster)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=12)

    # phase 1: train 6 steps, checkpoint at 3 and 6
    tc = TrainConfig(steps=6, checkpoint_every=3, log_every=1, opt=opt)
    state, hist = train_loop(model, data, tc, checkpointer=ck)
    assert ck.list_checkpoints() == ["step-3", "step-6"]

    # phase 2: a storage node dies hard; cluster keeps serving checkpoints
    cluster.crash_node("oss2")
    template = init_train_state(model, jax.random.PRNGKey(0), opt)
    restored = ck.restore("step-6", like=template)

    # phase 3: elastic rescale — add a node, re-protect data, retire another
    cluster.restart_node("oss2")
    cluster.add_node()
    cluster.scrub()
    cluster.remove_node("oss1")
    restored2 = ck.restore("step-6", like=template)
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(restored2)):
        np.testing.assert_array_equal(
            np.asarray(a).view(np.uint8) if np.asarray(a).dtype.name == "bfloat16" else np.asarray(a),
            np.asarray(b).view(np.uint8) if np.asarray(b).dtype.name == "bfloat16" else np.asarray(b),
        )

    # phase 4: resume training from the restored state
    tc2 = TrainConfig(steps=9, checkpoint_every=0, log_every=1, opt=opt)
    state2, hist2 = train_loop(model, data, tc2, state=restored2, start_step=6)
    assert all(np.isfinite(h["loss"]) for h in hist2)

    # phase 5: loss from resumed state matches continuous-run magnitude
    assert hist2[-1]["loss"] < hist[0]["loss"] + 0.5


def test_straggler_hedge_read_path():
    """Reads fall over to replicas when the primary is slow/dead (hedged
    request model: our read path tries placement order)."""
    cluster = DedupCluster.create(4, replicas=2, chunking=ChunkingSpec("fixed", 1024))
    import os

    data = os.urandom(4096)
    cluster.write_object("x", data)
    cluster.tick(2)
    # kill whichever node is primary for each chunk — replica must serve
    from repro.core import sha256_fp
    from repro.core.chunking import chunk_object

    for chunk in chunk_object(data, cluster.chunking):
        primary = cluster.chunk_targets(sha256_fp(chunk))[0]
        cluster.nodes[primary].alive = False
        assert cluster.read_object("x") == data
        cluster.nodes[primary].alive = True
