"""Batched write pipeline (`write_objects`) vs looped `write_object`:
identical fingerprints, refcounts, OMAP state, stored bytes and dedup
ratios — including under fault injection at the existing event points."""

import numpy as np
import pytest

from repro.core import ChunkingSpec, DedupCluster, TransactionAbort, WriteError

RNG = np.random.default_rng(99)


def _items(n=12, max_size=20000):
    items = [(f"o{i}", RNG.bytes(int(RNG.integers(0, max_size)))) for i in range(n)]
    # guaranteed duplicates: full and partial
    items.append(("dup-full", items[0][1]))
    items.append(("dup-cat", items[1][1] + items[2][1]))
    return items


def _assert_same_state(a: DedupCluster, b: DedupCluster, exact_net: bool = True):
    assert a.nodes.keys() == b.nodes.keys()
    for nid in a.nodes:
        na, nb = a.nodes[nid], b.nodes[nid]
        assert na.chunk_store == nb.chunk_store, nid
        cit_a = {fp: (e.refcount, e.flag, e.size) for fp, e in na.shard.cit.items()}
        cit_b = {fp: (e.refcount, e.flag, e.size) for fp, e in nb.shard.cit.items()}
        assert cit_a == cit_b, nid
        omap_a = {n: (e.object_fp, tuple(e.chunk_fps), e.size) for n, e in na.shard.omap.items()}
        omap_b = {n: (e.object_fp, tuple(e.chunk_fps), e.size) for n, e in nb.shard.omap.items()}
        assert omap_a == omap_b, nid
    assert a.unique_bytes_stored() == b.unique_bytes_stored()
    assert a.dedup_ratio() == b.dedup_ratio()
    # Cross-object coalescing turns intra-batch duplicate chunks into
    # ref-only ops: duplicate bytes never hit the wire, so the coalesced
    # batch may send strictly fewer net bytes than the serial loop.
    if exact_net:
        assert a.stats.net_bytes == b.stats.net_bytes
    else:
        assert a.stats.net_bytes >= b.stats.net_bytes
    assert a.stats.logical_bytes_written == b.stats.logical_bytes_written
    assert a.stats.writes_ok == b.stats.writes_ok
    assert a.stats.writes_failed == b.stats.writes_failed
    # lookup *operations* are batch-invariant; only message counts may shrink
    assert a.stats.lookup_unicasts == b.stats.lookup_unicasts
    assert a.stats.control_msgs >= b.stats.control_msgs


@pytest.mark.parametrize("spec", [ChunkingSpec("fixed", 1024), ChunkingSpec("cdc", 2048)],
                         ids=["fixed", "cdc"])
@pytest.mark.parametrize("replicas", [1, 2])
def test_batch_equals_serial(spec, replicas):
    items = _items()
    a = DedupCluster.create(4, replicas=replicas, chunking=spec)
    b = DedupCluster.create(4, replicas=replicas, chunking=spec)
    u = DedupCluster.create(4, replicas=replicas, chunking=spec,
                            coalesce_batches=False)
    fa = [a.write_object(n, d) for n, d in items]
    fb = b.write_objects(list(items))           # cross-object coalesced
    fu = u.write_objects(list(items))           # per-object unicasts (PR 1 shape)
    assert fa == fb == fu
    _assert_same_state(a, b, exact_net=False)
    _assert_same_state(a, u, exact_net=True)
    # the coalesced batch ships the duplicate objects' bytes zero times
    assert b.stats.net_bytes < u.stats.net_bytes
    assert b.stats.control_msgs < u.stats.control_msgs
    for n, d in items:
        assert b.read_object(n) == d


def test_batch_rewrite_and_idempotence_equal_serial():
    spec = ChunkingSpec("fixed", 512)
    items = _items(6, 4000)
    # rewrite same names with same + different content within one batch
    items += [items[0], ("o1", RNG.bytes(3000))]
    a = DedupCluster.create(3, chunking=spec)
    b = DedupCluster.create(3, chunking=spec)
    fa = [a.write_object(n, d) for n, d in items]
    fb = b.write_objects(list(items))
    assert fa == fb
    _assert_same_state(a, b, exact_net=False)


def test_write_object_is_thin_wrapper():
    c = DedupCluster.create(3, chunking=ChunkingSpec("fixed", 1024))
    data = RNG.bytes(5000)
    assert c.write_object("x", data) == c.write_objects([("y", data)])[0]
    assert c.read_object("x") == c.read_object("y") == data


def _abort_injector(event_name, target_name, index=None):
    def inj(event, ctx):
        if event == event_name and ctx.get("name") == target_name:
            if index is None or ctx.get("index") == index:
                raise TransactionAbort(f"injected at {event_name}")
    return inj


@pytest.mark.parametrize("event,index", [
    ("before_chunk_op", 3),
    ("after_chunk_op", 0),
    ("before_omap", None),
])
def test_batch_equals_serial_under_fault_injection(event, index):
    spec = ChunkingSpec("fixed", 1024)
    items = _items(6, 8000)
    victim = items[3][0]
    a = DedupCluster.create(4, chunking=spec)
    b = DedupCluster.create(4, chunking=spec)
    a.fault_injector = _abort_injector(event, victim, index)
    b.fault_injector = _abort_injector(event, victim, index)
    if len(items[3][1]) <= (index or 0) * 1024:
        items[3] = (victim, RNG.bytes(8192))  # ensure the indexed event fires
    fa = []
    for n, d in items:
        try:
            fa.append(a.write_object(n, d))
        except WriteError:
            fa.append(None)
    try:
        fb = b.write_objects(list(items))
        assert None not in fa and fb == fa  # injector never fired in either
    except WriteError:
        # batch raises at the failed item, exactly where the loop failed;
        # retrying the tail must reproduce the serial fingerprints
        done = b.stats.writes_ok + b.stats.writes_failed
        assert fa[done - 1] is None, "serial and batched must fail at the same item"
        fb_tail = [b.write_objects([(n, d)])[0] for n, d in items[done:]]
        assert fb_tail == fa[done:]
    # committed object fingerprints visible in OMAP match the serial returns
    omap_fps = {}
    for node in b.nodes.values():
        omap_fps.update({nm: e.object_fp for nm, e in node.shard.omap.items()})
    for (nm, _), f in zip(items, fa):
        if f is None:
            assert nm not in omap_fps
        else:
            assert omap_fps[nm] == f
    _assert_same_state(a, b)
    garbage_a = sum(len(n.shard.invalid_fps()) for n in a.nodes.values())
    garbage_b = sum(len(n.shard.invalid_fps()) for n in b.nodes.values())
    assert garbage_a == garbage_b


def test_batch_with_dead_node_equals_serial():
    spec = ChunkingSpec("fixed", 1024)
    items = _items(8, 10000)
    a = DedupCluster.create(5, replicas=2, chunking=spec)
    b = DedupCluster.create(5, replicas=2, chunking=spec)
    a.crash_node("oss2")
    b.crash_node("oss2")
    fa = [a.write_object(n, d) for n, d in items]
    fb = b.write_objects(list(items))
    assert fa == fb
    _assert_same_state(a, b, exact_net=False)
    for n, d in items:
        assert b.read_object(n) == d


def test_batch_write_then_gc_lifecycle():
    """Batched writes feed the same tagged-consistency machinery: flags flip
    on tick, deletes tombstone, GC collects."""
    c = DedupCluster.create(3, chunking=ChunkingSpec("fixed", 1024))
    items = [(f"o{i}", RNG.bytes(4096)) for i in range(4)]
    c.write_objects(items)
    assert sum(len(n.shard.invalid_fps()) for n in c.nodes.values()) > 0
    c.tick(2)
    assert sum(len(n.shard.invalid_fps()) for n in c.nodes.values()) == 0
    for n, _ in items:
        assert c.delete_object(n)
    c.tick(20); c.run_gc(); c.tick(20); c.run_gc()
    assert c.unique_bytes_stored() == 0


def test_empty_batch_and_empty_object():
    c = DedupCluster.create(3, chunking=ChunkingSpec("fixed", 1024))
    assert c.write_objects([]) == []
    fps = c.write_objects([("empty", b"")])
    assert c.read_object("empty") == b""
    assert len(fps) == 1


def test_dmshard_batch_cit_apis():
    """The batched CIT surface must mirror the scalar ops exactly."""
    from repro.core.dmshard import DMShard
    from repro.core.fingerprint import sha256_fp

    sh = DMShard()
    fps = [sha256_fp(bytes([i]) * 10) for i in range(4)]
    entries = sh.cit_insert_many([(fp, 10) for fp in fps], now=0)
    assert [e.refcount for e in entries] == [0] * 4
    assert sh.cit_lookup_many(fps) == entries
    assert sh.cit_lookup_many([sha256_fp(b"missing")]) == [None]
    assert sh.cit_addref_many(fps) == [1] * 4
    assert sh.cit_addref_many(fps, -1) == [0] * 4
    with pytest.raises(KeyError):
        sh.cit_insert_many([(fps[0], 10)], now=0)


def test_batch_unicasts_knob_forces_granular_messaging():
    """batch_unicasts=False reproduces the chunk-granular message shape
    (one unicast per chunk-replica op) with identical cluster state."""
    data = RNG.bytes(64 * 1024)
    granular = DedupCluster.create(8, chunking=ChunkingSpec("fixed", 1024),
                                   batch_unicasts=False)
    batched = DedupCluster.create(8, chunking=ChunkingSpec("fixed", 1024))
    granular.write_object("a", data)
    batched.write_object("a", data)
    assert granular.stats.lookup_unicasts == batched.stats.lookup_unicasts == 64
    assert granular.stats.control_msgs > batched.stats.control_msgs
    for nid in granular.nodes:
        assert granular.nodes[nid].chunk_store == batched.nodes[nid].chunk_store


def test_batched_node_api_within_batch_duplicates():
    """Duplicate fingerprints inside one batched unicast must behave exactly
    like sequential receive_chunk calls: the first stores, the second sees
    the still-INVALID entry with bytes present -> consistency-check repair
    (the flag flip is async, paper §2.4)."""
    from repro.core.fingerprint import sha256_fp
    from repro.core.node import StorageNode

    blob = b"x" * 100
    fp = sha256_fp(blob)
    batched = StorageNode("n0")
    serial = StorageNode("n1")
    outcomes = batched.receive_chunks([(fp, blob), (fp, blob)], now=0, txn_id=1)
    ref = [serial.receive_chunk(fp, blob, 0, 1), serial.receive_chunk(fp, blob, 0, 1)]
    assert outcomes == ref == ["stored", "repaired"]
    assert batched.shard.cit_lookup(fp).refcount == 2
    assert serial.shard.cit_lookup(fp).refcount == 2
    assert batched.shard.cit_lookup(fp).flag == serial.shard.cit_lookup(fp).flag
