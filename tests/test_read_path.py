"""Coalesced batch restore engine (DedupCluster.read_objects /
DedupClient.get_many).

The contract under test: the batched engine is byte-identical to the
serial read oracle (``batch_reads=False``) on every workload, while
collapsing the message count to one ChunkReadBatch per target node and
fetching every distinct chunk of a batch exactly once (the first-reader
cache). Degraded reads stay batched — per-fp misses walk to the next
replica in follow-up waves — and an all-replica miss composes with the
recovery subsystem (RepairChunk) exactly like the serial path.
"""

import numpy as np
import pytest

from repro.core import (
    ChunkingSpec,
    DedupCluster,
    INVALID,
    ReadError,
    VALID,
)

CH = ChunkingSpec("fixed", 1024)


def workload(seed=7, n_items=16, obj_bytes=4096, pool=4):
    """~50% duplicate chunks across objects (two pool blocks each)."""
    rng = np.random.default_rng(seed)
    blocks = [rng.bytes(obj_bytes // 2) for _ in range(pool)]
    return [
        (f"o{i}", blocks[i % pool] + blocks[(i + 1) % pool])
        for i in range(n_items)
    ]


def populated(items, **kw):
    c = DedupCluster.create(4, replicas=2, chunking=CH, **kw)
    c.write_objects(list(items))
    c.tick(3)
    return c


def read_deltas(c, names, **kw):
    m0, n0 = c.stats.control_msgs, c.stats.net_bytes
    a0 = c.stats.ack_bytes
    data = c.read_objects(names, **kw)
    return (
        data,
        c.stats.control_msgs - m0,
        c.stats.net_bytes - n0,
        c.stats.ack_bytes - a0,
    )


# ------------------------------------------------------------ equivalence
def test_batched_restore_byte_identical_to_serial_oracle():
    items = workload()
    names = [n for n, _ in items]
    serial = populated(items)
    serial.batch_reads = False
    oracle, msgs_serial, _, _ = read_deltas(serial, names)
    assert oracle == [d for _, d in items]

    batched = populated(items)
    got, msgs_batched, _, _ = read_deltas(batched, names)
    assert got == oracle
    # one OMAP probe per name either way; chunk fetches collapse from one
    # ChunkRead per recipe reference to one ChunkReadBatch per node
    assert msgs_serial / msgs_batched >= 3
    assert batched.stats.read_batches <= len(batched.nodes)
    assert batched.stats.read_fallback_rounds == 0
    assert batched.transport.msgs_by_type.get("chunk_read", 0) == 0


def test_first_reader_cache_fetches_each_distinct_chunk_once():
    """Duplicate chunk references across the batch travel the wire exactly
    once: the read payload equals the batch's DISTINCT chunk bytes, and
    fetch_elisions books every reference the cache absorbed."""
    items = workload()
    names = [n for n, _ in items]
    c = populated(items)

    recipes = [c._omap_lookup(n) for n in names]
    total_refs = sum(len(e.chunk_fps) for e in recipes)
    distinct = {fp for e in recipes for fp in e.chunk_fps}

    def chunk_len(fp):
        for n in c.nodes.values():
            b = n.chunk_store.get(fp)
            if b is not None:
                return len(b)
        raise AssertionError(f"chunk {fp} stored nowhere")

    distinct_bytes = sum(chunk_len(fp) for fp in distinct)

    _, msgs, net, acks = read_deltas(c, names)
    # request payloads are 0 for reads and net_bytes carries no control
    # headers (those are wire_bytes), so net - acks IS the response payload
    assert net - acks == distinct_bytes
    assert c.stats.fetch_elisions == total_refs - len(distinct)
    assert c.stats.fetch_elisions > 0

    # serial oracle pays for every reference
    s = populated(items)
    s.batch_reads = False
    _, msgs_s, net_s, acks_s = read_deltas(s, names)
    assert net_s - acks_s == sum(e.size for e in recipes)
    assert msgs_s > msgs


def test_fragmentation_records_per_object():
    items = workload(n_items=6)
    names = [n for n, _ in items]
    c = populated(items)
    frag = []
    data, *_ = read_deltas(c, names, frag_out=frag)
    assert [f["name"] for f in frag] == names
    recipes = [c._omap_lookup(n) for n in names]
    for f, e in zip(frag, recipes):
        assert f["chunks"] == len(e.chunk_fps)
        assert 1 <= f["nodes"] <= len(c.nodes)
        # the busiest node serves at least the mean share, at most all
        assert f["max_chunks_one_node"] * f["nodes"] >= f["chunks"]
        assert f["max_chunks_one_node"] <= f["chunks"]


# ---------------------------------------------------------- degraded reads
def test_per_fp_miss_walks_to_next_replica_in_fallback_round():
    items = workload(n_items=4)
    c = populated(items)
    entry = c._omap_lookup("o0")
    fp = entry.chunk_fps[0]
    first, second = c.chunk_targets(fp)[:2]
    # lose the bytes on the first replica only: the CIT survives, so the
    # first wave's reply reports a per-fp miss (not an exception) and ONLY
    # this fp is re-requested from the second replica
    c.nodes[first].chunk_store.pop(fp)
    data, *_ = read_deltas(c, [n for n, _ in items])
    assert data == [d for _, d in items]
    assert c.stats.read_fallback_rounds == 1


def test_crashed_node_excluded_at_plan_time():
    items = workload(n_items=6)
    c = populated(items)
    crashed = next(iter(c.nodes))
    c.crash_node(crashed)
    data = c.read_objects([n for n, _ in items])
    assert data == [d for _, d in items]
    # liveness was known at plan time: no wave was wasted on the dead node
    assert c.stats.read_fallback_rounds == 0


def test_repair_on_read_flag_flip_preserved_in_batch():
    """PR 4's repair-on-read: a hit on an INVALID-but-present chunk flips
    the flag back to VALID — the batched handler runs the same read-path
    consistency check per fp as the serial one."""
    items = workload(n_items=2)
    c = populated(items)
    fp = c._omap_lookup("o0").chunk_fps[0]
    target = c.chunk_targets(fp)[0]
    node = c.nodes[target]
    node.shard.cit_set_flag(fp, INVALID, c.now)
    repairs = node.stats.repairs
    assert c.read_object("o0") == items[0][1]
    assert node.shard.cit_lookup(fp).flag == VALID
    assert node.stats.repairs == repairs + 1


def test_all_replica_miss_falls_back_to_recovery_repair():
    """Satellite regression: an all-replica miss inside a ChunkReadBatch
    surfaces as ReadError (same failure surface as the serial walk), a
    recovery round repairs the chunk from the surviving copy (RepairChunk),
    and the retried batch succeeds."""
    items = workload(n_items=4)
    names = [n for n, _ in items]
    c = populated(items)
    fp = c._omap_lookup("o0").chunk_fps[0]
    first, second = c.chunk_targets(fp)[:2]
    c.nodes[first].chunk_store.pop(fp)   # bytes lost on one replica...
    c.crash_node(second)                 # ...and the other is down
    with pytest.raises(ReadError):
        c.read_objects(names)
    # recovery: the restarted replica's digest disagrees on has_bytes,
    # so scrub ships the chunk back to the degraded one
    c.restart_node(second)
    c.scrub()
    c.tick(3)
    assert c.transport.msgs_by_type.get("repair_chunk", 0) > 0
    assert fp in c.nodes[first].chunk_store
    assert c.read_objects(names) == [d for _, d in items]


def test_missing_object_raises_read_error():
    c = populated(workload(n_items=2))
    with pytest.raises(ReadError):
        c.read_objects(["o0", "nope"])
    with pytest.raises(ReadError):
        c.read_object("nope")


# ------------------------------------------------------------- client facade
def test_get_many_reads_your_writes_and_orders_results():
    c = DedupCluster.create(4, chunking=CH)
    s = c.client()
    s.put("a", b"x" * 2048)
    s.put("b", b"y" * 2048)
    # buffered puts drain before the batch restore plans anything
    assert s.get_many(["b", "a"]) == [b"y" * 2048, b"x" * 2048]
    s.close()


def test_batched_read_hits_teach_presence_cache():
    """Restored chunks are existence evidence: after a get_many, putting
    the same content through the session elides the CIT probes the
    presence cache now answers — a restore primes subsequent writes."""
    items = workload(n_items=8)
    c = populated(items)
    s = c.client(presence_cache=512)
    s.get_many([n for n, _ in items])
    pe0, lookups0 = c.stats.probe_elisions, c.stats.lookup_unicasts
    s.put_many([(f"copy{i}", d) for i, (_, d) in enumerate(items)])
    assert c.stats.probe_elisions > pe0
    s.close()

    # oracle without a presence cache: same writes carry full lookups
    c2 = populated(items)
    s2 = c2.client()
    s2.get_many([n for n, _ in items])
    l0 = c2.stats.lookup_unicasts
    s2.put_many([(f"copy{i}", d) for i, (_, d) in enumerate(items)])
    assert (c.stats.lookup_unicasts - lookups0) < (c2.stats.lookup_unicasts - l0)
    s2.close()


def test_empty_batch_and_empty_object():
    c = DedupCluster.create(2, chunking=CH)
    assert c.read_objects([]) == []
    c.write_object("empty", b"")
    assert c.read_objects(["empty"]) == [b""]
    assert c.stats.read_batches == 0  # nothing to fetch either time
