"""Cluster-wide dedup: transactions, refcounts, baselines."""

import os

import pytest

from repro.core import (
    CentralDedupCluster,
    ChunkingSpec,
    DedupCluster,
    DiskLocalDedupCluster,
    NoDedupCluster,
    ReadError,
)

CH = ChunkingSpec("fixed", 1024)


def mk(n=4, replicas=1, **kw):
    return DedupCluster.create(n, replicas=replicas, chunking=CH, **kw)


def test_write_read_roundtrip():
    c = mk()
    data = os.urandom(10_000)
    c.write_object("a", data)
    assert c.read_object("a") == data


def test_duplicate_objects_dedup():
    c = mk()
    data = os.urandom(8192)
    c.write_object("a", data)
    c.write_object("b", data)
    assert c.unique_bytes_stored() == 8192
    assert abs(c.space_savings() - 0.5) < 1e-9
    assert c.read_object("a") == c.read_object("b") == data


def test_partial_duplication():
    c = mk()
    head = os.urandom(4096)
    c.write_object("a", head + os.urandom(4096))
    c.write_object("b", head + os.urandom(4096))
    assert c.unique_bytes_stored() == 12288  # head shared


def test_refcounts_exact():
    c = mk()
    data = os.urandom(4096)
    c.write_object("a", data)
    c.write_object("b", data)
    c.write_object("c", data)
    for node in c.nodes.values():
        for fp, e in node.shard.cit.items():
            assert e.refcount == 3
    c.delete_object("b")
    for node in c.nodes.values():
        for fp, e in node.shard.cit.items():
            assert e.refcount == 2


def test_delete_to_zero_then_gc():
    c = mk()
    data = os.urandom(4096)
    c.write_object("a", data)
    c.tick(2)
    assert c.delete_object("a")
    c.tick(20)
    c.run_gc()
    c.tick(20)
    c.run_gc()
    assert c.unique_bytes_stored() == 0
    with pytest.raises(ReadError):
        c.read_object("a")


def test_rewrite_same_name_same_content_idempotent():
    c = mk()
    data = os.urandom(4096)
    c.write_object("a", data)
    c.write_object("a", data)
    for node in c.nodes.values():
        for fp, e in node.shard.cit.items():
            assert e.refcount == 1


def test_rewrite_same_name_new_content_replaces():
    c = mk()
    c.write_object("a", os.urandom(4096))
    new = os.urandom(4096)
    c.write_object("a", new)
    assert c.read_object("a") == new
    # old chunks tombstoned
    c.tick(20); c.run_gc(); c.tick(20); c.run_gc()
    assert c.unique_bytes_stored() == 4096


def test_write_by_ref_counts_and_reads():
    c = mk()
    data = os.urandom(4096)
    c.write_object("src", data)
    c.tick(2)
    assert c.write_object_by_ref("dst", "src") is not None
    assert c.read_object("dst") == data
    for node in c.nodes.values():
        for fp, e in node.shard.cit.items():
            assert e.refcount == 2
    # deleting src must not break dst
    c.delete_object("src")
    assert c.read_object("dst") == data


def test_lookup_is_unicast_never_broadcast():
    c = mk(8)
    c.write_object("a", os.urandom(64 * 1024))
    assert c.stats.lookup_broadcasts == 0
    # one lookup unicast per chunk-replica op
    assert c.stats.lookup_unicasts == 64


def test_replication_tolerates_node_loss():
    c = mk(5, replicas=3)
    data = os.urandom(20_000)
    c.write_object("a", data)
    c.tick(2)
    victims = list(c.nodes)[:2]
    for v in victims:
        c.crash_node(v)
    assert c.read_object("a") == data


def test_central_baseline_matches_savings_but_serializes():
    cw = mk(4)
    ce = CentralDedupCluster.create(4, chunking=CH)
    data = os.urandom(8192)
    for i in range(4):
        cw.write_object(f"o{i}", data)
        ce.write_object(f"o{i}", data)
    assert abs(cw.space_savings() - ce.space_savings()) < 1e-9
    assert ce.central_ops > 0 and ce.central_cpu_bytes == 4 * 8192
    assert ce.read_object("o0") == data


def test_disk_local_baseline_misses_cross_node_duplicates():
    dl = DiskLocalDedupCluster.create(8, chunking=CH)
    cw = mk(8)
    data = os.urandom(4096)
    for i in range(16):
        dl.write_object(f"obj-{i}", data)   # lands on many nodes by name
        cw.write_object(f"obj-{i}", data)
    assert cw.unique_bytes_stored() == 4096
    assert dl.unique_bytes_stored() > 4096  # duplicates across nodes missed
    assert dl.read_object("obj-3") == data


def test_nodedup_baseline():
    c = NoDedupCluster.create(4)
    data = os.urandom(4096)
    c.write_object("a", data)
    c.write_object("b", data)
    assert c.unique_bytes_stored() == 8192
    assert c.read_object("a") == data
