"""Chunked (flash-style) attention must match the dense path exactly."""

import dataclasses

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model
from repro.models.layers import AttnSpec, chunked_attention, init_attention, mha

RNG = np.random.default_rng(3)


def _spec(**kw):
    base = dict(d_model=64, n_heads=4, n_kv_heads=2, head_dim=16)
    base.update(kw)
    return AttnSpec(**base)


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 8), (False, 0)])
@pytest.mark.parametrize("q_chunk,kv_chunk", [(8, 8), (16, 4), (32, 32)])
def test_chunked_matches_dense_softmax(causal, window, q_chunk, kv_chunk):
    b, s, kh, rep, hd = 2, 32, 2, 2, 16
    q = jnp.asarray(RNG.standard_normal((b, s, kh, rep, hd)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, s, kh, hd)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, s, kh, hd)), jnp.float32)

    out = chunked_attention(q, k, v, causal=causal, window=window, mask_offset=0,
                            q_chunk=q_chunk, kv_chunk=kv_chunk, scale=0.25)
    # dense reference
    scores = jnp.einsum("bqkrh,bskh->bkrqs", q, k) * 0.25
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    ok = jnp.ones((s, s), bool)
    if causal:
        ok = ok & (ki <= qi)
    if window:
        ok = ok & (ki > qi - window)
    scores = jnp.where(ok[None, None, None], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    ref = jnp.moveaxis(jnp.einsum("bkrqs,bskh->bkrqh", w, v), 3, 1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_unroll_inner_matches_scan():
    b, s, kh, rep, hd = 1, 32, 2, 1, 16
    q = jnp.asarray(RNG.standard_normal((b, s, kh, rep, hd)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, s, kh, hd)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, s, kh, hd)), jnp.float32)
    a = chunked_attention(q, k, v, causal=True, window=0, mask_offset=0,
                          q_chunk=8, kv_chunk=8, scale=0.25, unroll_inner=False)
    bu = chunked_attention(q, k, v, causal=True, window=0, mask_offset=0,
                           q_chunk=8, kv_chunk=8, scale=0.25, unroll_inner=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bu), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("arch", ["qwen2.5-32b", "gemma3-12b", "minicpm3-4b"])
def test_model_level_dense_vs_chunked(arch):
    cfg_d = get_config(arch).reduced()
    cfg_c = dataclasses.replace(cfg_d, attn_impl="chunked", attn_q_chunk=16, attn_kv_chunk=8)
    md, mc = build_model(cfg_d), build_model(cfg_c)
    params = md.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg_d.vocab, (2, 32)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg_d.vocab, (2, 32)), jnp.int32),
    }
    ld, _ = md.loss_fn(params, batch)
    lc, _ = mc.loss_fn(params, batch)
    assert abs(float(ld) - float(lc)) < 2e-3, (float(ld), float(lc))


def test_v_head_dim_differs_from_qk():
    """MLA case: v head dim != qk head dim."""
    b, s, kh, rep, hd, vd = 1, 16, 3, 1, 24, 8
    q = jnp.asarray(RNG.standard_normal((b, s, kh, rep, hd)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, s, kh, hd)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, s, kh, vd)), jnp.float32)
    out = chunked_attention(q, k, v, causal=True, window=0, mask_offset=0,
                            q_chunk=8, kv_chunk=8, scale=0.2)
    assert out.shape == (b, s, kh, rep, vd)
    assert np.isfinite(np.asarray(out)).all()
