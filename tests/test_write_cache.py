"""Write-back chunk cache + fingerprint presence cache (DedupClient).

The safety property under test throughout: presence is an optimization
hint, never an authority. Whatever happens to the invalidation traffic —
dropped, duplicated, reordered, or never sent — a presence-enabled
session must end byte-identical to a cache-disabled oracle, with exact
refcounts; staleness may only cost fallback byte resends.
"""

import random

import pytest

from repro.core import (
    ChunkSpec,
    ChunkingSpec,
    DedupCluster,
    PresenceCache,
    PresenceInvalidate,
    chaos,
    chunk_object,
    drop,
    duplicate,
    fingerprint_many,
    reorder,
)

CH = ChunkingSpec("fixed", 1024)


def mk(n=4, **kw):
    return DedupCluster.create(n, chunking=CH, **kw)


def workload(seed=7, n_items=24, obj_bytes=4096, pool=8):
    """~50% duplicate chunks: each object concatenates two pool blocks."""
    rng = random.Random(seed)
    blocks = [rng.randbytes(obj_bytes // 2) for _ in range(pool)]
    return [
        (f"o{i}", blocks[i % pool] + blocks[(i + 1) % pool])
        for i in range(n_items)
    ]


def node_state(c):
    """Full logical state per node: live OMAP recipes, CIT refcounts,
    chunk-store bytes — the byte-identical comparison surface."""
    out = {}
    for nid, n in sorted(c.nodes.items()):
        omap = {
            name: (e.object_fp, tuple(e.chunk_fps), e.version)
            for name, e in n.shard.omap.items()
            if not e.deleted
        }
        cit = {
            fp: (e.refcount, e.flag, e.size)
            for fp, e in n.shard.cit.items()
        }
        out[nid] = (omap, cit, dict(n.chunk_store))
    return out


def assert_refs_exact(c):
    """No dangling or leaked refs: every node's CIT refcounts equal the
    recipe references across all live OMAP entries cluster-wide."""
    expected = {}
    for n in c.nodes.values():
        for e in n.shard.omap.values():
            if e.deleted:
                continue
            for fp in e.chunk_fps:
                expected[fp] = expected.get(fp, 0) + 1
    for nid, n in c.nodes.items():
        for fp, e in n.shard.cit.items():
            assert e.refcount == expected.get(fp, 0), (
                f"{nid}: {fp} refcount {e.refcount} != expected "
                f"{expected.get(fp, 0)}"
            )
            assert fp in n.chunk_store, f"{nid}: {fp} entry without bytes"


# --------------------------------------------------------------- PresenceCache


def fps_of(data):
    return fingerprint_many(chunk_object(data, CH))


def test_presence_cache_lru_and_counters():
    p = PresenceCache(2)
    a, b, c = fps_of(random.Random(1).randbytes(3 * 1024))[:3]
    assert not p.hit(a) and p.misses == 1
    p.note(a)
    p.note(b)
    assert p.hit(a) and p.hits == 1          # a is MRU now
    p.note(c)                                # evicts b (LRU)
    assert len(p) == 2 and p.evictions == 1
    assert not p.hit(b)
    assert p.hit(a) and p.hit(c)


def test_presence_cache_invalidate_idempotent():
    p = PresenceCache(8)
    fps = fps_of(random.Random(2).randbytes(3 * 1024))
    for fp in fps:
        p.note(fp)
    assert p.invalidate_many(fps) == len(fps)
    assert p.invalidate_many(fps) == 0        # second pass is a no-op
    assert len(p) == 0 and p.invalidations == len(fps)


def test_presence_cache_rejects_nonpositive_capacity():
    with pytest.raises(ValueError):
        PresenceCache(0)


# ------------------------------------------------------------------ ChunkSpec


def test_chunkspec_core_convention_matches_chunkingspec():
    legacy = ChunkingSpec("cdc", 8192).normalized()
    spec = ChunkSpec.cdc(8192)
    assert (spec.min_bytes, spec.max_bytes) == (legacy.min_size, legacy.max_size)
    assert spec.to_chunking() == legacy
    data = random.Random(3).randbytes(100_000)
    assert chunk_object(data, spec) == chunk_object(data, legacy)


def test_chunkspec_checkpoint_convention():
    spec = ChunkSpec.for_checkpoint(512 * 1024)
    assert spec.kind == "cdc" and spec.device
    assert spec.min_bytes == 512 * 1024 // 2
    assert spec.max_bytes == 512 * 1024 * 2
    # legacy device_cdc=False mapped to fixed-size chunking
    fixed = ChunkSpec.for_checkpoint(4096, device=False)
    assert fixed.kind == "fixed" and fixed.target_bytes == 4096


def test_chunkspec_kernel_kwargs_roundtrip():
    from repro.core.chunking import cdc_mask

    spec = ChunkSpec.cdc(8192, min_bytes=1000, max_bytes=20000)
    kw = spec.kernel_kwargs()
    assert kw == {"mask": cdc_mask(8192), "min_size": 1000, "max_size": 20000}
    assert ChunkSpec.from_chunking(spec.to_chunking()) == spec


def test_kernel_entry_points_accept_spec():
    import numpy as np

    from repro.kernels import ops as kops

    data = np.frombuffer(random.Random(5).randbytes(50_000), dtype=np.uint8)
    spec = ChunkSpec.cdc(4096)
    via_spec = kops.cdc_cut_offsets(data, spec=spec)
    via_raw = kops.cdc_cut_offsets(data, **spec.kernel_kwargs())
    assert list(via_spec) == list(via_raw)
    with pytest.raises(TypeError):
        kops.cdc_cut_offsets(data)            # neither spelling given


# ----------------------------------------------------------- stats snapshot


def test_stats_snapshot_deterministic_and_complete():
    items = workload()
    c1, c2 = mk(), mk()
    c1.write_objects(items)
    c2.write_objects(items)
    c1.read_objects([n for n, _ in items])
    c2.read_objects([n for n, _ in items])
    s1, s2 = c1.stats.snapshot(), c2.stats.snapshot()
    assert s1 == s2
    for col in (
        "lookup_unicasts",
        "control_msgs",
        "net_bytes",
        "probe_elisions",
        "cache_hits",
        "cache_evictions",
        "presence_fallbacks",
        "peak_dirty_bytes",
        "read_batches",
        "read_fallback_rounds",
        "fetch_elisions",
    ):
        assert col in s1
    assert s1["read_batches"] > 0
    assert s1["fetch_elisions"] > 0  # the 50%-dup workload shares chunks


# ------------------------------------------------------------ client facade


def test_put_is_write_back_until_flush():
    c = mk()
    s = c.client()
    s.put("a", b"x" * 4096)
    assert c.stats.writes_ok == 0, "put must buffer, not write"
    with pytest.raises(Exception):
        c.read_object("a")
    fps = s.flush()
    assert set(fps) == {"a"}
    assert c.read_object("a") == b"x" * 4096
    assert s.get("a") == b"x" * 4096


def test_put_auto_flushes_at_wave_bytes():
    c = mk()
    s = c.client(wave_bytes=8 * 1024)
    for i in range(4):
        s.put(f"a{i}", b"y" * 4096)
    assert c.stats.writes_ok >= 2, "buffer must auto-flush at the bound"
    s.close()
    assert c.stats.writes_ok == 4


def test_get_and_delete_drain_pending():
    c = mk()
    s = c.client()
    s.put("a", b"z" * 2048)
    assert s.get("a") == b"z" * 2048          # read-your-writes
    s.put("b", b"w" * 2048)
    assert s.delete("b") or True              # drained then deleted
    assert c.stats.writes_ok == 2


def test_closed_session_rejects_use():
    c = mk()
    s = c.client()
    s.close()
    s.close()                                  # idempotent
    with pytest.raises(RuntimeError):
        s.put("a", b"x")


def test_shim_parity_with_client_session():
    """write_objects (the deprecated shim) and a cache-disabled client must
    produce identical state AND identical message accounting."""
    items = workload()
    c1, c2 = mk(), mk()
    c1.write_objects(items)
    s = c2.client()
    s.put_many(items)
    assert c1.stats.snapshot() == c2.stats.snapshot()
    assert node_state(c1) == node_state(c2)


# ------------------------------------------------------- presence elision


def test_presence_elides_probes_and_matches_oracle():
    """Bounded waves + presence: chunks repeated across waves are elided
    (a single unbounded wave's intra-wave repeats are already ref-only via
    the first-writer set, so presence only matters across waves)."""
    items = workload()
    oracle, cached = mk(), mk()
    fps1 = oracle.write_objects(items)
    s = cached.client(presence_cache=256, wave_bytes=16 * 1024)
    fps2 = s.put_many(items)
    assert fps1 == fps2
    assert node_state(oracle) == node_state(cached)
    assert cached.stats.probe_elisions > 0
    assert cached.stats.lookup_unicasts < oracle.stats.lookup_unicasts
    assert (
        cached.stats.lookup_unicasts + cached.stats.probe_elisions
        == oracle.stats.lookup_unicasts
    ), "every elision must account for exactly one skipped probe"
    assert_refs_exact(cached)


def test_presence_elision_is_deterministic():
    items = workload()
    runs = []
    for _ in range(2):
        c = mk()
        s = c.client(presence_cache=256, wave_bytes=16 * 1024)
        s.put_many(items)
        runs.append(c.stats.snapshot())
    assert runs[0] == runs[1]
    assert runs[0]["probe_elisions"] > 0


def test_presence_helps_across_batches():
    """The cross-batch case the wave-local first-writer set cannot cover:
    batch 2 rewrites batch 1's content under new names."""
    items = workload(n_items=12)
    c = mk()
    s = c.client(presence_cache=256)
    s.put_many(items)
    before = c.stats.probe_elisions
    s.put_many([(f"n{i}", data) for i, (_, data) in enumerate(items)])
    assert c.stats.probe_elisions > before
    oracle = mk()
    oracle.write_objects(items)
    oracle.write_objects([(f"n{i}", d) for i, (_, d) in enumerate(items)])
    assert node_state(oracle) == node_state(c)
    assert_refs_exact(c)


def test_presence_eviction_bounds_capacity():
    items = workload(n_items=16)
    c = mk()
    s = c.client(presence_cache=4)
    s.put_many(items)
    assert len(s.presence) <= 4
    assert c.stats.cache_evictions > 0
    oracle = mk()
    oracle.write_objects(items)
    assert node_state(oracle) == node_state(c)


# ------------------------------------------------------------ invalidation


def test_delete_invalidates_presence():
    items = workload(n_items=8)
    c = mk()
    s = c.client(presence_cache=256)
    s.put_many(items)
    assert len(s.presence) > 0
    c.delete_object("o0")
    assert s.invalidations_received >= 1
    assert c.stats.cache_invalidations > 0
    # re-writing the deleted content stays correct
    s.put_many([("o0", items[0][1])])
    assert c.read_object("o0") == items[0][1]
    assert_refs_exact(c)


def test_gc_reclaim_invalidates_presence():
    c = mk()
    s = c.client(presence_cache=256)
    data = random.Random(11).randbytes(4096)
    s.put_many([("a", data)])
    assert len(s.presence) > 0
    c.delete_object("a")
    after_delete = s.invalidations_received
    threshold = max(n.gc.threshold for n in c.nodes.values())
    c.run_gc()                       # scan: held set observes the invalids
    c.tick(threshold + 1)            # age past the threshold
    removed = c.run_gc()             # sweep: physically reclaim
    assert sum(len(v) for v in removed.values()) > 0, "GC must reclaim"
    assert s.invalidations_received > after_delete, (
        "GC reclaim must fan out its own invalidation"
    )
    # the chunks are physically gone; a presence-hit write must still work
    s.put_many([("b", data)])
    assert c.read_object("b") == data
    assert_refs_exact(c)


def test_tombstone_reap_invalidates_presence():
    """The last-chance path: the session misses the delete-time fan-out
    (drop only=PresenceInvalidate during the delete), and learns via the
    reap's retained-fps response instead."""
    from repro.core import reliable

    c = DedupCluster.create(4, replicas=2, chunking=CH)
    s = c.client(presence_cache=256)
    data = random.Random(13).randbytes(4096)
    s.put_many([("x", data)])
    c.tick(2)
    c.transport.policy = drop(1.0, only=(PresenceInvalidate,))
    assert c.delete_object("x")
    assert s.invalidations_received == 0, "delete-time fan-out was dropped"
    c.transport.policy = reliable()
    horizon = max(n.gc.tombstone_horizon for n in c.nodes.values())
    c.tick(horizon + 1)
    rep = c.recover()
    assert rep.tombstones_reaped > 0
    assert s.invalidations_received >= 1, (
        "reap must fan out the tombstone's retained fps"
    )


# ------------------------------------------------- staleness under chaos


def test_stale_presence_falls_back_to_byte_resend():
    """Invalidations all lost + chunks GC'd: the next presence hit is a
    receiver-side miss; the writer must resend bytes and converge to the
    oracle — stale presence costs traffic, never correctness."""
    c = mk()
    s = c.client(presence_cache=256)
    data = random.Random(17).randbytes(8192)
    s.put_many([("a", data)])
    c.transport.policy = drop(1.0, only=(PresenceInvalidate,))
    c.delete_object("a")
    threshold = max(n.gc.threshold for n in c.nodes.values())
    c.run_gc()                       # scan
    c.tick(threshold + 1)            # age
    removed = c.run_gc()             # reclaim (invalidation fan-out dropped)
    assert sum(len(v) for v in removed.values()) > 0, "GC must reclaim"
    assert s.invalidations_received == 0 and len(s.presence) > 0, (
        "precondition: the cache is stale"
    )
    # use a second name alongside, so the coalesced wave path runs
    s.put_many([("b", data), ("c", random.Random(18).randbytes(4096))])
    assert c.stats.presence_fallbacks > 0, "stale hits must fall back"
    assert c.read_object("b") == data
    oracle = mk()
    oracle.write_object("a", data)
    oracle.delete_object("a")
    oracle.run_gc()
    oracle.tick(threshold + 1)
    oracle.run_gc()
    oracle.write_objects([("b", data), ("c", random.Random(18).randbytes(4096))])
    c.tick(2)       # drain async commit-flag flips on both sides
    oracle.tick(2)
    assert node_state(oracle) == node_state(c)
    assert_refs_exact(c)


def test_invalidation_handler_idempotent_under_duplicate_and_reorder():
    items = workload(n_items=10)
    for policy in (
        duplicate(1.0, only=(PresenceInvalidate,)),
        reorder(0.5, seed=3, only=(PresenceInvalidate,)),
    ):
        c = mk()
        s = c.client(presence_cache=256)
        s.put_many(items)
        c.transport.policy = policy
        for name, _ in items[:4]:
            c.delete_object(name)
        c.tick(4)  # land held/duplicated copies
        oracle = mk()
        oracle.write_objects(items)
        for name, _ in items[:4]:
            oracle.delete_object(name)
        oracle.tick(4)
        assert node_state(oracle) == node_state(c)
        assert_refs_exact(c)


def test_chaos_with_presence_matches_oracle():
    """Full chaos on the invalidation traffic only; writes stay reliable so
    the comparison is exact. State must equal the cache-disabled oracle."""
    items = workload(n_items=20)
    c = mk()
    s = c.client(presence_cache=256)
    c.transport.policy = chaos(seed=5, only=(PresenceInvalidate,))
    s.put_many(items)
    for name, _ in items[:6]:
        c.delete_object(name)
    s.put_many([(f"r{i}", d) for i, (_, d) in enumerate(items[:6])])
    c.tick(6)
    oracle = mk()
    oracle.write_objects(items)
    for name, _ in items[:6]:
        oracle.delete_object(name)
    oracle.write_objects([(f"r{i}", d) for i, (_, d) in enumerate(items[:6])])
    oracle.tick(6)
    assert node_state(oracle) == node_state(c)
    assert_refs_exact(c)


# --------------------------------------------------------- bounded memory


def test_streaming_waves_bound_peak_dirty_bytes():
    items = workload(n_items=32, obj_bytes=4096)
    wave = 8 * 1024
    c = mk()
    s = c.client(wave_bytes=wave)
    fps = s.put_many(items)
    assert len(fps) == len(items)
    assert s.wcache.waves_emitted > 1, "the batch must split into waves"
    max_obj = max(len(d) for _, d in items)
    assert c.stats.peak_dirty_bytes <= wave + max_obj, (
        f"peak dirty {c.stats.peak_dirty_bytes} exceeds wave bound {wave} "
        f"+ one-object slack {max_obj}"
    )
    oracle = mk()
    oracle.write_objects(items)
    assert node_state(oracle) == node_state(c)
    # the unbounded legacy shape materializes the whole batch
    assert oracle.stats.peak_dirty_bytes >= sum(len(d) for _, d in items)


def test_wave_splits_at_repeated_name():
    c = mk()
    s = c.client()
    data1, data2 = b"1" * 2048, b"2" * 2048
    s.put_many([("a", data1), ("b", data1), ("a", data2)])
    assert c.read_object("a") == data2, "last write wins across waves"
    assert s.wcache.waves_emitted == 2


# ------------------------------------------------------ checkpoint session


def test_checkpoint_streams_waves_and_keeps_state():
    jax = pytest.importorskip("jax")
    import numpy as np

    from repro.checkpoint.dedup_ckpt import CheckpointConfig, DedupCheckpointer

    tree = {
        f"layer{i}": np.arange(4096, dtype=np.float32) + i for i in range(6)
    }
    c1 = DedupCluster.create(4, chunking=CH)
    ck1 = DedupCheckpointer(
        c1, CheckpointConfig(device_fp_fastpath=False, wave_bytes=32 * 1024)
    )
    ck1.save("step1", tree)
    c2 = DedupCluster.create(4, chunking=CH)
    ck2 = DedupCheckpointer(c2, CheckpointConfig(device_fp_fastpath=False))
    ck2.save("step1", tree)
    got = ck1.restore("step1")
    for k in tree:
        assert np.array_equal(np.asarray(got[f"['{k}']"] if f"['{k}']" in got else got[k]), tree[k])
    assert ck1.session is not None and ck1.session.wcache.waves_emitted > 1
    assert c1.stats.peak_dirty_bytes < c2.stats.peak_dirty_bytes
    assert node_state(c1) == node_state(c2)
