"""Multi-tenant workload generator tests (core/workload.py).

Three claims, in increasing strength:

1. *Interleaving is real*: N sessions hold sent-but-uncommitted waves at
   the same tick (the scheduler event log is the witness), and wave k+1
   chunking overlaps wave k in flight (``stats.waves_overlapped``).
2. *Parity*: a single cache-disabled session driven through the
   scheduler is message-identical to the legacy call-driven path — the
   refactor changed the execution model, not the protocol.
3. *Convergence*: any seeded interleaving's final state equals a serial
   replay of its version-sorted commit log (the split-brain oracle
   extended to concurrent sessions), including under a chaos transport.
"""

import os
import random

import pytest

from repro.core import (
    ChunkingSpec,
    DedupCluster,
    ReadError,
    Scheduler,
    WorkloadSpec,
    chaos,
    reliable,
    run_workload,
)
from repro.core.workload import _gen_client_ops, _block_pool

CH = ChunkingSpec("fixed", 2048)


def pytest_generate_tests(metafunc):
    """Workload chaos schedules are seeded like the transport suites:
    small fixed set locally, widened by the nightly job via
    WORKLOAD_SCHEDULES / WORKLOAD_SEED_BASE (disjoint from the other
    sweeps' seed ranges). A failing test id names the seed."""
    if "workload_seed" in metafunc.fixturenames:
        base = int(os.environ.get("WORKLOAD_SEED_BASE", "0"))
        n = int(os.environ.get("WORKLOAD_SCHEDULES", "4"))
        metafunc.parametrize("workload_seed", range(base, base + n))


def _fresh(n=4, replicas=2, policy=None):
    return DedupCluster.create(n, replicas=replicas, chunking=CH, policy=policy)


def _spec(**kw):
    base = dict(
        clients=8, objects=24, ops_per_client=8, seed=5,
        bulk_first=2, wave_bytes=8192,
    )
    base.update(kw)
    return WorkloadSpec(**base)


def _live_state(c):
    """name -> bytes for every readable live object (reliable reads)."""
    c.transport.policy = reliable()
    out = {}
    names = sorted({n for nd in c.nodes.values() for n in nd.shard.omap})
    for name in names:
        try:
            out[name] = c.read_object(name)
        except ReadError:
            pass
    return out


def _replay_oracle(commit_log, n=4, replicas=2):
    """Serial replay of the version-sorted commit log into a fresh
    cluster: the serializable history every interleaving must equal."""
    oc = _fresh(n, replicas)
    for _version, kind, name, data in commit_log:
        if kind == "put":
            oc.write_object(name, data)
        else:
            oc.delete_object(name)
    return oc


# ---------------------------------------------------------- interleaving
def test_eight_clients_interleave_with_waves_in_flight():
    c = _fresh()
    sched = Scheduler(c, seed=5)
    rep = run_workload(c, _spec(), scheduler=sched)
    assert rep["max_in_flight_sessions"] >= 2
    # the event log itself shows >= 2 sessions in flight at one tick
    assert any(len(labels) >= 2 for _, _, labels in sched.event_log)
    # wave k+1 chunked while wave k was in flight (PR 8's serialization
    # caveat, now pipelined)
    assert c.stats.waves_overlapped >= 1
    assert rep["totals"]["puts_ok"] >= 1 and rep["totals"]["gets_ok"] >= 1
    assert rep["edges"]["busiest_edge_payload"] > 0
    assert rep["edges"]["node_ingress_max"] > 0
    # every client made progress and reported latency percentiles
    for pc in rep["per_client"]:
        assert pc["ops"] >= 1
        assert pc["latency_p99_ticks"] >= pc["latency_p50_ticks"] >= 1


def test_same_spec_seed_reproducible_report():
    c1 = _fresh()
    r1 = run_workload(c1, _spec())
    c2 = _fresh()
    r2 = run_workload(c2, _spec())
    assert r1 == r2
    assert c1.stats.snapshot() == c2.stats.snapshot()


def test_seen_window_occupancy_tracks_in_flight_depth():
    """The sizing study's test-side anchor: peak seen-window occupancy
    grows with concurrent client count (more in-flight ids), evictions
    stay zero throughout, and the 8-client peak keeps clear headroom in
    the 1024-id window. The measured points themselves are pinned as
    tolerance-0 columns by bench_multi_tenant."""
    highs = {}
    for nclients in (2, 4, 8):
        c = _fresh()
        run_workload(c, _spec(clients=nclients))
        assert c.stats.seen_evictions == 0
        highs[nclients] = c.stats.seen_high_water
    assert highs[2] <= highs[4] <= highs[8]
    assert highs[2] < highs[8], "occupancy must respond to concurrency"
    assert highs[8] < 1024


# ---------------------------------------------------------------- parity
def test_single_session_actor_is_message_identical_to_sync():
    """The refactor's pin: one cache-disabled session driven through the
    scheduler produces byte-for-byte the same message counts, OMAP and
    chunk stores as the legacy synchronous path. Overlap is the ONLY new
    behavior (a counter, not a message)."""
    rng = random.Random(9)
    items = [(f"o{i}", rng.randbytes(3000 + 512 * (i % 5))) for i in range(12)]

    c1 = _fresh()
    s1 = c1.client(wave_bytes=8192)
    fps_sync = s1.put_many(list(items))
    s1.close()

    c2 = _fresh()
    s2 = c2.client(wave_bytes=8192)
    sched = Scheduler(c2, seed=0)
    sched.spawn(s2.put_wave_actor(list(items)), name="s", session=s2)
    fps_actor, committed = sched.run()["s"]
    s2.close()

    assert fps_actor == fps_sync
    assert [n for n, _ in committed] == [n for n, _ in items]
    snap1, snap2 = c1.stats.snapshot(), c2.stats.snapshot()
    overlapped = snap2.pop("waves_overlapped")
    snap1.pop("waves_overlapped")
    assert snap1 == snap2
    assert overlapped >= 1
    # advance the sync cluster through the same elapsed ticks so both
    # flip queues drain, then require identical durable state
    c1.tick(c2.now - c1.now)

    def durable(c):
        return {
            nid: (
                {n: (e.version, e.object_fp, tuple(e.chunk_fps))
                 for n, e in nd.shard.omap.items()},
                {fp: (e.refcount, e.flag) for fp, e in nd.shard.cit.items()},
                dict(nd.chunk_store),
            )
            for nid, nd in c.nodes.items()
        }

    assert durable(c1) == durable(c2)


# ----------------------------------------------------------- convergence
@pytest.mark.parametrize("sched_seed", [3, 11, 25])
def test_interleaving_converges_to_serial_replay(sched_seed):
    """Split-brain oracle, concurrent edition: whatever interleaving the
    seed produces, replaying the version-sorted commit log serially into
    a fresh cluster reproduces the live state byte-identically after
    recovery — commit authority is the version counter, not arrival
    order."""
    c = _fresh()
    sched = Scheduler(c, seed=sched_seed)
    rep = run_workload(c, _spec(), scheduler=sched)
    c.recover()
    oracle = _replay_oracle(rep["commit_log"])
    assert _live_state(c) == _live_state(oracle)


@pytest.mark.parametrize("sched_seed", range(4))
def test_background_gc_and_repair_interleave_safely(sched_seed):
    """Regression: a repair round scheduled inside a session's send→commit
    window must not audit-decref the wave's not-yet-committed refs (the
    chunk mtimes predate the round start, so the ``exclude_after`` epoch
    gate alone misses them — the in-flight wave registry closes the gap).
    Before the fix this died with a negative-refcount assertion in the
    client's own later delete. Recurring GC + repair actors interleave
    with 8 client sessions; no actor may error, and the result must still
    converge to the serial replay oracle."""
    c = _fresh()
    sched = Scheduler(c, seed=sched_seed)
    spec = _spec(gc_interval=5, repair_interval=7)
    rep = run_workload(c, spec, scheduler=sched)
    assert not sched.errors, sched.errors
    assert not c._inflight_wave_fps, "in-flight registry leaked past the run"
    c.recover()
    oracle = _replay_oracle(rep["commit_log"])
    assert _live_state(c) == _live_state(oracle)


@pytest.mark.parametrize("sched_seed", range(3))
def test_background_actors_survive_chaos(sched_seed):
    """The chaos edition of the regression above, plus the ack-loss case:
    a wave whose ChunkOpBatch ack is lost gets its unconfirmed replica
    ref cancelled, yet the object commits on the replicas that acked —
    so its later replace/delete releases a ref that replica never kept.
    The receiver must treat that as the missed-incref divergence the
    refcount audit repairs (``decrefs_unbacked``), not drive the count
    negative and kill the client actor."""
    c = _fresh(policy=chaos(seed=9 + sched_seed, p_drop=0.04, p_dup=0.04,
                            p_reorder=0.04, p_ack_drop=0.04))
    sched = Scheduler(c, seed=sched_seed)
    spec = _spec(gc_interval=5, repair_interval=7)
    rep = run_workload(c, spec, scheduler=sched)
    assert not rep["actor_errors"], rep["actor_errors"]
    assert not c._inflight_wave_fps
    c.transport.policy = reliable()
    c.recover()
    r2 = c.recover()
    assert r2.refs_over == 0 and r2.refs_under == 0


def test_unbacked_decref_is_tolerated_not_negative():
    """Direct unit form of the ack-loss release race: a replica whose
    refcount is already zero receiving a DecrefBatch for a committed
    recipe's chunk must no-op (counted in ``decrefs_unbacked``) and leave
    the entry flagged for GC aging, because the sender's recipe — not the
    under-replicated replica — is the authority the reference existed."""
    c = _fresh()
    c.write_object("obj", b"z" * 2048)
    fp = next(fp for nd in c.nodes.values() for fp in nd.shard.cit)
    owners = [nid for nid in c.nodes if fp in c.nodes[nid].shard.cit]
    victim = c.nodes[owners[0]]
    # Simulate the settled cancel: this replica compensated its ack-lost
    # application, so its count is 0 while the recipe still commits.
    victim.decref_chunk(fp, c.now)
    assert victim.shard.cit_lookup(fp).refcount == 0
    before = victim.stats.decrefs_unbacked
    c.delete_object("obj")  # releases on every placement target
    assert victim.stats.decrefs_unbacked == before + 1
    assert victim.shard.cit_lookup(fp).refcount == 0


def test_workload_chaos_sweep(workload_seed):
    """Multi-client chaos: 6 sessions race puts/gets/deletes over a
    lossy, duplicating, reordering transport. Committed-visibility and
    integrity invariants must hold after recovery:

    * every live object's bytes equal some value a client actually
      generated for that name (no torn or cross-object merges);
    * for each name, the cluster's version authority is at least the
      highest version any client saw committed (commits are durable);
    * a name whose highest committed record is a delete cannot be live
      at that version or below (deletes don't silently undo);
    * a second recovery round is a fixpoint.
    """
    spec = _spec(clients=6, ops_per_client=6, seed=workload_seed + 100)
    c = _fresh(policy=chaos(seed=workload_seed, p_drop=0.06, p_dup=0.06,
                            p_reorder=0.06, p_ack_drop=0.06))
    rep = run_workload(c, spec)
    assert not rep["actor_errors"], (
        f"client actor died under chaos: {rep['actor_errors']} "
        f"(repro: WORKLOAD_SEED_BASE={workload_seed} WORKLOAD_SCHEDULES=1)"
    )
    c.transport.policy = reliable()
    c.recover()

    # regenerate the deterministic op streams: every value any client
    # could have written for each name
    pool = _block_pool(spec)
    valid = {}
    for i in range(spec.clients):
        for op in _gen_client_ops(spec, i, pool):
            for name, data in op.items:
                valid.setdefault(name, set()).add(data)
    live = _live_state(c)
    for name, data in live.items():
        assert data in valid.get(name, set()), (
            f"live {name!r} holds bytes no client generated "
            f"(repro: WORKLOAD_SEED_BASE={workload_seed} WORKLOAD_SCHEDULES=1)"
        )

    def version_of(name):
        return max(
            (e.version for nd in c.nodes.values()
             if (e := nd.shard.omap.get(name)) is not None),
            default=0,
        )

    top = {}
    for version, kind, name, _data in rep["commit_log"]:
        top[name] = (version, kind)
    for name, (version, kind) in sorted(top.items()):
        assert version_of(name) >= version, (
            f"committed v{version} {kind} of {name!r} lost "
            f"(repro: WORKLOAD_SEED_BASE={workload_seed} WORKLOAD_SCHEDULES=1)"
        )
        if kind == "delete" and name in live:
            assert version_of(name) > version, (
                f"delete v{version} of {name!r} undone "
                f"(repro: WORKLOAD_SEED_BASE={workload_seed})"
            )

    before = _live_state(c)
    c.recover()
    assert _live_state(c) == before, "second recovery round is not a fixpoint"
