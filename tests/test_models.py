"""Per-arch smoke tests (reduced configs) + decode-path consistency."""

import dataclasses

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, get_config, shape_applicable
from repro.configs.base import ShapeSpec
from repro.models import build_model

ALL_ARCHS = sorted(ARCHS)


def _batch_for(m, shape, rng):
    specs = m.input_specs(shape)
    out = {}
    for k, s in specs.items():
        if s.dtype == jnp.int32:
            out[k] = jnp.asarray(rng.integers(0, m.cfg.vocab, size=s.shape), jnp.int32)
        else:
            out[k] = jnp.asarray(rng.standard_normal(s.shape), s.dtype)
    return out


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    shape = ShapeSpec("t", 32, 2, "train")
    batch = _batch_for(m, shape, rng)
    loss, metrics = m.loss_fn(params, batch)
    assert np.isfinite(float(loss)), arch
    assert float(metrics["tokens"]) > 0


@pytest.mark.parametrize("arch", [a for a in ALL_ARCHS if ARCHS[a].has_decode])
def test_smoke_decode_step(arch):
    cfg = get_config(arch).reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    dshape = ShapeSpec("d", 32, 2, "decode")
    cs = m.cache_specs(dshape)
    caches = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), cs,
                          is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    logits, caches2 = m.decode_step(params, caches, jnp.ones((2, 1), jnp.int32), jnp.int32(3))
    assert logits.shape[0] == 2 and logits.shape[1] == 1
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    # cache structure preserved
    assert jax.tree.structure(caches) == jax.tree.structure(caches2)


@pytest.mark.parametrize(
    "arch", ["qwen2.5-32b", "gemma3-12b", "mamba2-1.3b", "recurrentgemma-2b", "minicpm3-4b"]
)
def test_prefill_decode_consistency(arch):
    cfg = get_config(arch).reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    S_PRE, S_ALL = 16, 24
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(2, S_ALL)), jnp.int32)
    _, caches = m.prefill(params, {"tokens": toks[:, :S_PRE]}, cache_len=S_ALL)
    lg = None
    for t in range(S_PRE, S_ALL):
        lg, caches = m.decode_step(params, caches, toks[:, t:t + 1], jnp.int32(t))
    ref_logits, _ = m.prefill(params, {"tokens": toks}, cache_len=S_ALL)
    a = np.asarray(lg[:, 0], np.float32)
    b = np.asarray(ref_logits[:, 0], np.float32)
    err = np.max(np.abs(a - b)) / (np.max(np.abs(b)) + 1e-6)
    assert err < 0.05, (arch, err)


def test_moe_consistency_without_capacity_drops():
    cfg = dataclasses.replace(get_config("qwen2-moe-a2.7b").reduced(), capacity_factor=16.0)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(2, 24)), jnp.int32)
    _, caches = m.prefill(params, {"tokens": toks[:, :16]}, cache_len=24)
    lg = None
    for t in range(16, 24):
        lg, caches = m.decode_step(params, caches, toks[:, t:t + 1], jnp.int32(t))
    ref_logits, _ = m.prefill(params, {"tokens": toks}, cache_len=24)
    err = np.max(np.abs(np.asarray(lg[:, 0], np.float32) - np.asarray(ref_logits[:, 0], np.float32)))
    err /= np.max(np.abs(np.asarray(ref_logits[:, 0], np.float32))) + 1e-6
    assert err < 0.05, err


def test_unroll_layers_equivalence():
    """The dry-run costing variant (python loop) must equal lax.scan."""
    cfg = get_config("gemma3-12b").reduced()
    m1 = build_model(cfg)
    m2 = build_model(dataclasses.replace(cfg, unroll_layers=True))
    params = m1.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)), jnp.int32),
    }
    l1, _ = m1.loss_fn(params, batch)
    l2, _ = m2.loss_fn(params, batch)
    assert abs(float(l1) - float(l2)) < 1e-3, (float(l1), float(l2))


def test_local_attention_ring_cache_exactness():
    """Ring-buffer local cache must match full recompute past one window."""
    cfg = dataclasses.replace(
        get_config("gemma3-12b").reduced(), window=8,
        block_pattern=("attn_local",), n_layers=2,
    )
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(2))
    rng = np.random.default_rng(1)
    S_ALL = 32  # 4 windows deep
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(1, S_ALL)), jnp.int32)
    _, caches = m.prefill(params, {"tokens": toks[:, :16]}, cache_len=S_ALL)
    lg = None
    for t in range(16, S_ALL):
        lg, caches = m.decode_step(params, caches, toks[:, t:t + 1], jnp.int32(t))
    ref_logits, _ = m.prefill(params, {"tokens": toks}, cache_len=S_ALL)
    a = np.asarray(lg[:, 0], np.float32)
    b = np.asarray(ref_logits[:, 0], np.float32)
    err = np.max(np.abs(a - b)) / (np.max(np.abs(b)) + 1e-6)
    assert err < 0.05, err


def test_shape_applicability_table():
    # 40 cells: count runnable vs skipped and pin expectations
    runnable = {(a, s) for a in ALL_ARCHS for s in SHAPES
                if shape_applicable(ARCHS[a], s)[0]}
    skipped = {(a, s) for a in ALL_ARCHS for s in SHAPES} - runnable
    assert ("mamba2-1.3b", "long_500k") in runnable
    assert ("gemma3-12b", "long_500k") in runnable
    assert ("recurrentgemma-2b", "long_500k") in runnable
    assert ("qwen2.5-32b", "long_500k") in skipped
    assert ("qwen1.5-110b", "long_500k") in skipped
    assert ("whisper-tiny", "long_500k") in skipped
    assert len(runnable) + len(skipped) == 40


def test_vocab_padding_is_sharding_friendly_and_masked():
    cfg = get_config("mamba2-1.3b")
    assert cfg.padded_vocab % 256 == 0 and cfg.padded_vocab >= cfg.vocab
    red = cfg.reduced()
    m = build_model(red)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, red.vocab, (1, 16)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, red.vocab, (1, 16)), jnp.int32),
    }
    loss, _ = m.loss_fn(params, batch)
    assert np.isfinite(float(loss))
