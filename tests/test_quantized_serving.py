"""w8a16 weight quantization + int8 KV cache serving modes."""

import dataclasses

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.models import build_model


def _greedy_decode(m, params, toks, cfg, n=24):
    cs = m.cache_specs(ShapeSpec("d", 32, 2, "decode"))
    caches = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), cs,
                          is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    lg = None
    for t in range(n):
        lg, caches = m.decode_step(params, caches, toks[:, t:t + 1], jnp.int32(t))
    return np.asarray(lg[:, 0], np.float32)


@pytest.mark.parametrize("arch", ["qwen2.5-32b", "minicpm3-4b"])
def test_w8_weights_close_to_bf16(arch):
    cfg = get_config(arch).reduced()
    cfg_q = dataclasses.replace(cfg, weight_quant=True)
    mb, mq = build_model(cfg), build_model(cfg_q)
    params_b = mb.init(jax.random.PRNGKey(0))
    params_q = mq.init(jax.random.PRNGKey(0))
    # quantized tree carries int8 weights + scales
    n_int8 = sum(1 for x in jax.tree.leaves(params_q) if x.dtype == jnp.int8)
    assert n_int8 > 0
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 24)), jnp.int32)
    a = _greedy_decode(mb, params_b, toks, cfg)
    b = _greedy_decode(mq, params_q, toks, cfg_q)
    err = np.max(np.abs(a - b)) / (np.max(np.abs(b)) + 1e-6)
    assert err < 0.2, err
    assert (a.argmax(-1) == b.argmax(-1)).mean() >= 0.5


def test_w8_and_kv8_combined():
    cfg = dataclasses.replace(get_config("qwen2.5-32b").reduced(),
                              weight_quant=True, kv_cache_quant=True)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32)
    lg = _greedy_decode(m, params, toks, cfg, n=16)
    assert np.isfinite(lg).all()


def test_w8_halves_weight_bytes():
    cfg = get_config("qwen2.5-32b").reduced()
    cfg_q = dataclasses.replace(cfg, weight_quant=True)
    size = lambda m: sum(
        x.size * x.dtype.itemsize for x in jax.tree.leaves(m.param_specs()))
    assert size(build_model(cfg_q)) < 0.65 * size(build_model(cfg))
