"""Unit + property tests: fingerprints, chunking, placement."""

import hashlib

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.chunking import ChunkingSpec, chunk_object, window_hash_at
from repro.core.fingerprint import Fingerprint, chain_fp, name_fp, object_fp, sha256_fp
from repro.core.placement import ClusterMap, place, primary


def test_sha256_fp_deterministic_and_distinct():
    a, b = sha256_fp(b"hello"), sha256_fp(b"hello")
    assert a == b and a.namespace == "sha256" and len(a.value) == 16
    assert sha256_fp(b"hellp") != a


def test_namespaces_never_collide():
    raw = hashlib.sha256(b"x").digest()[:16]
    assert Fingerprint("sha256", raw) != Fingerprint("device", raw)


def test_object_fp_order_sensitive():
    f1, f2 = sha256_fp(b"a"), sha256_fp(b"b")
    assert object_fp([f1, f2]) != object_fp([f2, f1])


def test_chain_fp_prefix_sensitivity():
    blk = sha256_fp(b"block")
    assert chain_fp(None, blk) != chain_fp(sha256_fp(b"prefix"), blk)


@given(st.binary(min_size=0, max_size=5000), st.integers(min_value=64, max_value=1024))
@settings(max_examples=40, deadline=None)
def test_fixed_chunking_lossless(data, size):
    chunks = chunk_object(data, ChunkingSpec("fixed", size))
    assert b"".join(chunks) == data
    assert all(len(c) <= size for c in chunks)
    assert all(len(c) == size for c in chunks[:-1])


@given(st.binary(min_size=1, max_size=8000))
@settings(max_examples=20, deadline=None)
def test_cdc_chunking_lossless_and_bounded(data):
    spec = ChunkingSpec("cdc", 256).normalized()
    chunks = chunk_object(data, spec)
    assert b"".join(chunks) == data
    assert all(len(c) <= spec.max_size for c in chunks)


def test_cdc_boundary_stability_under_prefix_insert():
    """Content-defined: inserting a prefix must not re-chunk the far tail."""
    import numpy as np

    rng = np.random.default_rng(7)
    base = rng.bytes(6000)
    spec = ChunkingSpec("cdc", 256)
    a = set(sha256_fp(c) for c in chunk_object(base, spec))
    b = set(sha256_fp(c) for c in chunk_object(rng.bytes(97) + base, spec))
    # a good CDC shares most chunks; fixed-size chunking would share none
    assert len(a & b) >= len(a) // 2


def test_window_hash_locality():
    data = bytes(range(256)) * 4
    # same 32-byte window => same hash regardless of what precedes it
    h1 = window_hash_at(data, 200)
    h2 = window_hash_at(b"\xff" * 100 + data[100:], 200)
    assert h1 == h2


# ------------------------------------------------------------ placement ----
def _cmap(n, replicas=1):
    return ClusterMap(1, tuple(f"n{i}" for i in range(n)), replicas=replicas)


def test_placement_deterministic():
    m = _cmap(8)
    fp = sha256_fp(b"chunk")
    assert place(fp, m, 3) == place(fp, m, 3)


def test_placement_replicas_distinct():
    m = _cmap(8)
    got = place(sha256_fp(b"c"), m, 3)
    assert len(set(got)) == 3


@given(st.binary(min_size=1, max_size=64))
@settings(max_examples=50, deadline=None)
def test_placement_minimal_movement(data):
    """Adding a node moves a chunk only if the new node wins (HRW property)."""
    fp = sha256_fp(data)
    m8 = _cmap(8)
    m9 = m8.with_node("n8")
    p8, p9 = primary(fp, m8), primary(fp, m9)
    assert p9 == p8 or p9 == "n8"


def test_placement_balance():
    m = _cmap(8)
    counts = {n: 0 for n in m.nodes}
    for i in range(4000):
        counts[primary(sha256_fp(str(i).encode()), m)] += 1
    avg = 4000 / 8
    for n, c in counts.items():
        assert 0.7 * avg < c < 1.3 * avg, (n, c)


def test_placement_weights_respected():
    m = ClusterMap(1, ("a", "b"), weights={"a": 3.0, "b": 1.0})
    wins = sum(primary(sha256_fp(str(i).encode()), m) == "a" for i in range(2000))
    assert 0.65 < wins / 2000 < 0.85  # ~0.75 expected


def test_fingerprint_determines_location_across_epochs():
    """The paper's core claim: placement is a pure function of (fp, map) —
    no stored locations anywhere."""
    fp = name_fp("some-object")
    m = _cmap(6, replicas=2)
    assert place(fp, m) == place(fp, ClusterMap(99, m.nodes, replicas=2))
