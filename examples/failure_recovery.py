"""Failure-injection drill for the storage substrate — every failure mode
the paper's tagged consistency must survive, end to end:

  1. crash before async flag flip      -> repair on duplicate write
  2. transaction abort mid-object      -> garbage chunks -> GC collects
  3. node dies permanently             -> replicas serve; scrub re-protects
  4. topology change under load        -> zero metadata-location rewrites

    PYTHONPATH=src python examples/failure_recovery.py
"""

import os

from repro.core import ChunkingSpec, DedupCluster, TransactionAbort, WriteError
from repro.core.placement import place

cluster = DedupCluster.create(4, replicas=2, chunking=ChunkingSpec("fixed", 64 * 1024))
payload = os.urandom(1 << 20)

# -- 1. crash window between data write and async flag flip -----------------
cluster.write_object("doc-v1", payload)          # flips still queued
for node in cluster.nodes.values():
    node.crash()                                 # power failure: queue lost
for node in cluster.nodes.values():
    node.restart()
invalid = sum(len(n.shard.invalid_fps()) for n in cluster.nodes.values())
print(f"[1] invalid flags after crash: {invalid} (chunks on disk, flips lost)")
cluster.write_object("doc-v2", payload)          # duplicate write repairs
repairs = sum(n.stats.repairs for n in cluster.nodes.values())
print(f"[1] consistency-check repairs: {repairs}; read-back ok: "
      f"{cluster.read_object('doc-v1') == payload}")

# -- 2. failed transaction leaves garbage; GC collects it --------------------
def bomb(event, ctx):
    if event == "before_chunk_op" and ctx["name"] == "doomed" and ctx["index"] == 8:
        raise TransactionAbort("client died mid-write")

cluster.fault_injector = bomb
try:
    cluster.write_object("doomed", os.urandom(1 << 20))
except WriteError as e:
    print(f"[2] transaction failed as injected: {type(e).__name__}")
cluster.fault_injector = None
garbage = sum(len(n.shard.invalid_fps()) for n in cluster.nodes.values())
cluster.tick(20); cluster.run_gc()
cluster.tick(20)
collected = sum(len(v) for v in cluster.run_gc().values())
print(f"[2] garbage chunks: {garbage}, GC collected: {collected} "
      f"(no journal, flags were the garbage markers)")

# -- 3. permanent node loss ---------------------------------------------------
victim = list(cluster.nodes)[1]
cluster.crash_node(victim)
print(f"[3] {victim} dead; read ok: {cluster.read_object('doc-v1') == payload}")
cluster.restart_node(victim)
cluster.nodes[victim].chunk_store.clear()        # disk wiped
cluster.nodes[victim].shard.cit.clear()
restored = cluster.scrub()
print(f"[3] scrub restored {restored} chunk copies to the replacement disk")

# -- 4. elastic rescale under data -------------------------------------------
before = sum(len(n.chunk_store) for n in cluster.nodes.values())
cluster.add_node()
moved = cluster.stats.rebalance_chunks_moved
print(f"[4] +1 node: moved {moved}/{before} chunk copies "
      f"({100*moved/max(before,1):.0f}%, HRW minimal movement)")
for nid, node in cluster.nodes.items():
    for fp in node.shard.cit:
        assert nid in place(fp, cluster.cmap), "metadata off placement!"
print("[4] every CIT entry located purely by place(fp, map): 0 location rewrites")
print(f"final read ok: {cluster.read_object('doc-v2') == payload}")
print("failure_recovery OK")
