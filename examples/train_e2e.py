"""End-to-end training driver: train a LM with deduplicated distributed
checkpointing, kill a storage node mid-run, resume from the dedup store.

Default runs a ~10M-param model for 60 steps (CPU-friendly). The ~100M
configuration from the deliverable spec:

    PYTHONPATH=src python examples/train_e2e.py --dim 640 --layers 10 \
        --vocab 32768 --steps 200 --seq 128 --batch 2
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.checkpoint import DedupCheckpointer
from repro.configs.base import ModelConfig
from repro.core import ChunkingSpec, DedupCluster
from repro.data import SyntheticLMData
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.train import TrainConfig, train_loop
from repro.train.loop import init_train_state


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ckpt-every", type=int, default=20)
    args = ap.parse_args()

    cfg = ModelConfig(
        arch_id="e2e-lm", family="dense", n_layers=args.layers, d_model=args.dim,
        n_heads=max(4, args.dim // 64), n_kv_heads=max(2, args.dim // 128),
        d_ff=args.dim * 4, vocab=args.vocab, tie_embeddings=True,
    ).validate()
    model = build_model(cfg)
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(
        jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))))
    print(f"model: {cfg.n_layers}L d={cfg.d_model} vocab={cfg.vocab} "
          f"params={n_params/1e6:.1f}M")

    data = SyntheticLMData(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch)
    cluster = DedupCluster.create(4, replicas=2, chunking=ChunkingSpec("fixed", 256 * 1024))
    ck = DedupCheckpointer(cluster)
    opt = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=args.steps)

    half = args.steps // 2
    print(f"--- phase 1: steps 0..{half} ---")
    tcfg = TrainConfig(steps=half, checkpoint_every=args.ckpt_every,
                       log_every=max(1, half // 6), opt=opt)
    state, hist = train_loop(model, data, tcfg, checkpointer=ck)
    for h in hist:
        print(f"  step {h['step']:4d} loss {h['loss']:.4f}")

    ckpts = ck.list_checkpoints()
    print(f"checkpoints: {ckpts}; cluster savings {100*cluster.space_savings():.1f}%")

    print("--- simulating storage-node failure + elastic replacement ---")
    cluster.crash_node("oss3")
    cluster.add_node()          # replacement joins; HRW moves ~1/5 of chunks
    cluster.scrub()             # restore replication factor

    last = ckpts[-1]
    template = init_train_state(model, jax.random.PRNGKey(0), opt)
    state = ck.restore(last, like=template)
    start = int(last.split("-")[-1])
    print(f"restored {last} from the degraded cluster (repair via replicas)")

    print(f"--- phase 2: steps {start}..{args.steps} (resumed) ---")
    tcfg2 = TrainConfig(steps=args.steps, checkpoint_every=args.ckpt_every,
                        log_every=max(1, half // 6), opt=opt)
    state, hist2 = train_loop(model, data, tcfg2, checkpointer=ck,
                              state=state, start_step=start)
    for h in hist2:
        print(f"  step {h['step']:4d} loss {h['loss']:.4f}")

    print(f"final ckpts: {ck.list_checkpoints()}")
    print(f"ckpt stats: {ck.stats}")
    print(f"dedup space savings: {100*cluster.space_savings():.1f}%")
    print("train_e2e OK")


if __name__ == "__main__":
    main()
