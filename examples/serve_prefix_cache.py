"""Serving with cluster-wide KV prefix-cache dedup: many requests sharing a
system prompt reuse each other's KV blocks — across serving replicas —
because block identity is the chain fingerprint of token content.

    PYTHONPATH=src python examples/serve_prefix_cache.py
"""

import jax
import numpy as np

from repro.configs import get_config
from repro.core import ChunkingSpec, DedupCluster
from repro.models import build_model
from repro.serving import BatchedServer, ServeConfig

cfg = get_config("qwen2.5-32b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
cluster = DedupCluster.create(4, chunking=ChunkingSpec("fixed", 64 * 1024))
server = BatchedServer(model, params, cluster, ServeConfig(max_len=128, block_tokens=8))

rng = np.random.default_rng(0)
system_prompt = [int(t) for t in rng.integers(0, cfg.vocab, 48)]  # shared prefix

print("request | reused | computed | (prefix tokens reused from the cluster)")
for i in range(8):
    user_suffix = [int(t) for t in rng.integers(0, cfg.vocab, 8)]
    r = server.handle(system_prompt + user_suffix, gen_tokens=8)
    print(f"  {i:4d}  |  {r['reused_tokens']:4d}  |   {r['computed_tokens']:4d}")

s = server.kv.stats
print(f"\nblock hit rate      : {s.hit_rate:.1%}")
print(f"tokens reused       : {s.tokens_reused}")
print(f"tokens recomputed   : {s.tokens_computed}")
print(f"KV store unique MB  : {cluster.unique_bytes_stored()/1e6:.2f} "
      f"(logical {cluster.stats.logical_bytes_written/1e6:.2f})")

# a node dies; prefix blocks remain reachable via placement on survivors
victim = list(cluster.nodes)[0]
cluster.crash_node(victim)
r = server.handle(system_prompt + [1, 2, 3, 4, 5, 6, 7, 8], gen_tokens=4)
print(f"\nafter {victim} crash: reused={r['reused_tokens']} (served from replicas/recompute)")
print("serve_prefix_cache OK")
