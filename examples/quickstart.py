"""Quickstart: cluster-wide dedup in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py
"""

import os

import jax

from repro.checkpoint import DedupCheckpointer
from repro.configs import get_config
from repro.core import ChunkingSpec, DedupCluster
from repro.models import build_model

# 1. A shared-nothing storage cluster: 4 OSS nodes, 2-way replication.
cluster = DedupCluster.create(4, replicas=2, chunking=ChunkingSpec("fixed", 64 * 1024))

# 2. Objects are chunked, content-fingerprinted, and placed cluster-wide by
#    fingerprint. Duplicate content is stored once — across ALL nodes.
blob = os.urandom(1 << 20)
cluster.write_object("vm-image-a", blob)
cluster.write_object("vm-image-b", blob)          # full duplicate
cluster.write_object("vm-image-c", blob + os.urandom(1 << 18))  # 80% duplicate
cluster.tick(2)                                    # async commit-flag flips

print(f"logical bytes written : {cluster.stats.logical_bytes_written/1e6:7.2f} MB")
print(f"unique bytes stored   : {cluster.unique_bytes_stored()/1e6:7.2f} MB")
print(f"space savings         : {100*cluster.space_savings():7.1f} %")
assert cluster.read_object("vm-image-b") == blob

# 3. Fault tolerance: a node dies; reads fall over to replicas.
cluster.crash_node("oss1")
assert cluster.read_object("vm-image-a") == blob
cluster.restart_node("oss1")
print("node failure survived : reads served from replicas")

# 4. Elastic scaling: add a node — chunks rebalance by pure placement math,
#    dedup metadata needs ZERO location updates (the paper's key property).
cluster.add_node()
assert cluster.read_object("vm-image-c")[: 1 << 20] == blob
print(f"rebalance moved       : {cluster.stats.rebalance_chunks_moved} chunks, "
      f"metadata rewrites: 0")

# 5. The framework integration: deduplicated model checkpoints.
model = build_model(get_config("qwen2.5-32b").reduced())
params = model.init(jax.random.PRNGKey(0))
ck = DedupCheckpointer(cluster)
ck.save("step-100", params)
ck.save("step-200", params)   # unchanged tensors -> reference-only writes
print(f"ckpt ref-only leaves  : {ck.stats['leaves_ref_only']} "
      f"(device-fingerprint fast path, no data motion)")
print("quickstart OK")
