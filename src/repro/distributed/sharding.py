"""Logical-axis sharding rules (DP / FSDP / TP / EP / SP).

Model code annotates tensors with *logical* axis names ("batch", "seq",
"d_model", "heads", "d_ff", "vocab", "experts", ...). A ShardingRules table
maps logical names onto mesh axes; `shard(x, *logical)` applies a
with_sharding_constraint when a rules context is active and is a no-op
otherwise (single-device smoke tests).

Default production mapping (DESIGN.md §3):
  batch   -> ("pod", "data")      # DP over pods and the data axis
  embed_in/d_ff/heads/vocab -> "model"   # TP
  stacked-layer param leading axis -> None (scan axis)
  fsdp    -> "data"               # FSDP: weight matrices additionally
                                  # sharded over the data axis on d_model
  experts -> "model"              # EP: experts live on the TP axis
  seq     -> None for train; "data" for long-context decode (B=1)
"""

from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field, replace

import jax
from jax.sharding import PartitionSpec as P

Axis = str | tuple[str, ...] | None


@dataclass(frozen=True)
class ShardingRules:
    rules: dict[str, Axis] = field(default_factory=dict)
    axis_sizes: dict[str, int] = field(default_factory=dict)

    def axis(self, logical: str | None) -> Axis:
        if logical is None:
            return None
        return self.rules.get(logical)

    def spec(self, *logical: str | None) -> P:
        return P(*(self.axis(name) for name in logical))

    def with_overrides(self, **kw: Axis) -> "ShardingRules":
        return ShardingRules({**self.rules, **kw}, self.axis_sizes)

    def axis_size(self, axis: Axis) -> int:
        if axis is None:
            return 1
        if isinstance(axis, str):
            return self.axis_sizes.get(axis, 1)
        n = 1
        for a in axis:
            n *= self.axis_sizes.get(a, 1)
        return n

    def guard_spec(self, spec: P, shape: tuple[int, ...]) -> P:
        """Drop mesh axes that do not divide the corresponding dim, and drop
        duplicate uses of a mesh axis (each axis may shard one dim only)."""
        out = []
        used: set[str] = set()
        for i, axis in enumerate(spec):
            if axis is None or i >= len(shape):
                out.append(None)
                continue
            if shape[i] % self.axis_size(axis) != 0:
                out.append(None)
                continue
            names = (axis,) if isinstance(axis, str) else tuple(axis)
            if any(n in used for n in names):
                out.append(None)
                continue
            used.update(names)
            out.append(axis)
        return P(*out)


def make_rules(
    *,
    data_axes: Axis = ("pod", "data"),
    model_axis: Axis = "model",
    fsdp_axis: Axis = "data",
    seq_axis: Axis = None,
    kv_seq_axis: Axis = None,
    expert_axis: Axis = "model",
) -> ShardingRules:
    return ShardingRules(
        {
            # activations
            "batch": data_axes,
            "seq": seq_axis,
            "kv_seq": kv_seq_axis,
            # scan-carry residual stream between block groups; sharding this
            # over "model" = sequence parallelism for the remat-saved buffers
            "residual_seq": None,
            "d_model": None,
            "act_d_ff": model_axis,
            "act_heads": model_axis,
            "act_vocab": model_axis,
            "act_experts": expert_axis,
            "act_state": None,
            # params
            "embed_vocab": model_axis,
            "embed_d": fsdp_axis,
            "w_in": fsdp_axis,        # d_model fan-in dim of matrices
            "w_out": model_axis,      # sharded output dim (heads*hd / d_ff)
            "w_in2": model_axis,      # fan-in that is already TP-sharded
            "w_out2": fsdp_axis,      # projection back to d_model
            "experts": expert_axis,   # leading experts dim of MoE params
            "layers": None,           # scan-stacked leading axis
            "heads": model_axis,
            "state": None,
            "norm": None,
        }
    )


_ctx = threading.local()


def current_rules() -> ShardingRules | None:
    return getattr(_ctx, "rules", None)


@contextlib.contextmanager
def use_sharding_rules(rules: ShardingRules | None):
    prev = current_rules()
    _ctx.rules = rules
    try:
        yield rules
    finally:
        _ctx.rules = prev


def shard(x: jax.Array, *logical: str | None) -> jax.Array:
    """Annotate activation x with logical axes; no-op without a rules ctx.
    Axes that don't divide the dimension are dropped (e.g. 6 whisper heads
    on a 16-way model axis)."""
    rules = current_rules()
    if rules is None:
        return x
    spec = rules.guard_spec(rules.spec(*logical), x.shape)
    if all(a is None for a in spec):
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def param_sharding(logical: tuple[str | None, ...]) -> P:
    rules = current_rules()
    if rules is None:
        return P()
    return rules.spec(*logical)


def activation_sharding(*logical: str | None) -> P:
    rules = current_rules()
    if rules is None:
        return P()
    return rules.spec(*logical)


# -------------------------------------------------------------------------
# Parameter-spec inference: path heuristics + divisibility guard. Covers the
# whole model zoo (dense/MLA/MoE/SSM/RG-LRU/enc-dec) and the optimizer state
# mirrors (mu/nu/master share leaf paths with params).
# -------------------------------------------------------------------------

_DOWN_PROJ_PARENTS = {"down", "wo", "out_proj"}


def infer_param_spec(path: tuple[str, ...], shape: tuple[int, ...], rules: ShardingRules) -> P:
    keys = [k for k in path if not k.isdigit()]
    last = keys[-1] if keys else ""
    parent = keys[-2] if len(keys) >= 2 else ""

    def base() -> tuple[Axis, ...]:
        r = rules.rules
        if last == "table":                       # embedding (V, D)
            return (r.get("embed_vocab"), r.get("embed_d"))
        if last in ("gate", "up") and parent == "experts":   # (E, D, F)
            return (r.get("experts"), r.get("w_in"), None)
        if last == "down" and parent == "experts":           # (E, F, D)
            return (r.get("experts"), None, r.get("w_out2"))
        if last == "conv_w":                      # (K, C)
            return (None, r.get("w_out"))
        if last == "w" and parent in _DOWN_PROJ_PARENTS:     # (f, D)
            return (r.get("w_in2"), r.get("w_out2"))
        if last == "w":                           # generic up-proj (D, f)
            return (r.get("w_in"), r.get("w_out"))
        if last == "b" and parent not in _DOWN_PROJ_PARENTS:
            return (r.get("w_out"),)
        return tuple(None for _ in shape)

    spec = list(base())
    # stacked leading axes (scan groups / layer stacks): pad on the left
    while len(spec) < len(shape):
        spec.insert(0, rules.rules.get("layers"))
    spec = spec[: len(shape)]
    return rules.guard_spec(P(*spec), shape)


def param_specs_for_tree(tree, rules: ShardingRules):
    """Map a pytree of ShapeDtypeStructs/arrays -> pytree of PartitionSpec."""

    def leaf_spec(path, leaf):
        keys = tuple(getattr(p, "key", getattr(p, "idx", str(p))) for p in path)
        keys = tuple(str(k) for k in keys)
        return infer_param_spec(keys, tuple(leaf.shape), rules)

    return jax.tree_util.tree_map_with_path(leaf_spec, tree)
