from repro.distributed.sharding import (
    ShardingRules,
    activation_sharding,
    current_rules,
    param_sharding,
    shard,
    use_sharding_rules,
)

__all__ = [
    "ShardingRules",
    "activation_sharding",
    "current_rules",
    "param_sharding",
    "shard",
    "use_sharding_rules",
]
