"""State-space sequence mixers: Mamba-2 (SSD) and RG-LRU (RecurrentGemma).

Mamba-2 uses the chunked state-space-duality algorithm: quadratic
attention-like math *within* chunks (MXU-friendly) and a linear recurrence
*across* chunks (lax.scan). RG-LRU uses a gated linear recurrence evaluated
with jax.lax.associative_scan for parallel prefill. Both have O(1)-state
single-token decode paths — which is why their archs run the long_500k shape.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.distributed import shard
from repro.models.layers import dense, init_dense


# =========================================================== Mamba-2 (SSD) ==
@dataclasses.dataclass(frozen=True)
class MambaSpec:
    d_model: int
    d_state: int = 128         # N
    head_dim: int = 64         # P
    expand: int = 2
    d_conv: int = 4
    chunk: int = 128           # Q (SSD chunk length)
    n_groups: int = 1          # G (B/C groups)

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def conv_channels(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state


def init_mamba(key, s: MambaSpec, dtype):
    ki, ko, kc, kd = jax.random.split(key, 4)
    d_in = s.d_inner
    proj_out = 2 * d_in + 2 * s.n_groups * s.d_state + s.n_heads
    return {
        "in_proj": init_dense(ki, s.d_model, proj_out, dtype),
        "conv_w": (jax.random.normal(kc, (s.d_conv, s.conv_channels), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((s.conv_channels,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, s.n_heads, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((s.n_heads,), jnp.float32),
        "d_skip": jnp.ones((s.n_heads,), jnp.float32),
        "norm_scale": jnp.ones((d_in,), dtype),
        "out_proj": init_dense(ko, d_in, s.d_model, dtype),
    }


def _split_proj(s: MambaSpec, zxbcdt):
    d_in, gn = s.d_inner, s.n_groups * s.d_state
    z = zxbcdt[..., :d_in]
    x = zxbcdt[..., d_in : 2 * d_in]
    bb = zxbcdt[..., 2 * d_in : 2 * d_in + gn]
    cc = zxbcdt[..., 2 * d_in + gn : 2 * d_in + 2 * gn]
    dt = zxbcdt[..., 2 * d_in + 2 * gn :]
    return z, x, bb, cc, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv. xbc: (B,S,C); w: (K,C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    y = sum(pad[:, i : i + xbc.shape[1], :] * w[i][None, None, :] for i in range(k))
    return jax.nn.silu(y + b[None, None, :])


def _gated_norm(scale, y, z, eps=1e-6):
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    yf = y.astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(y.dtype)


def mamba_prefill(p, s: MambaSpec, u: jnp.ndarray, return_cache: bool = False):
    """u: (B,S,D) -> (B,S,D) via chunked SSD. S must be a multiple of chunk
    (transformer.py pads). Final state returned as decode cache."""
    bsz, sl, _ = u.shape
    q = s.chunk
    assert sl % q == 0, (sl, q)
    nc = sl // q
    z, x, bb, cc, dt_raw = _split_proj(s, dense(p["in_proj"], u))
    xbc = _causal_conv(jnp.concatenate([x, bb, cc], axis=-1), p["conv_w"], p["conv_b"])
    x = xbc[..., : s.d_inner]
    bb = xbc[..., s.d_inner : s.d_inner + s.n_groups * s.d_state]
    cc = xbc[..., s.d_inner + s.n_groups * s.d_state :]

    h, pdim, n = s.n_heads, s.head_dim, s.d_state
    xh = x.reshape(bsz, nc, q, h, pdim)
    xh = shard(xh, "batch", None, None, "act_heads", None)
    bg = bb.reshape(bsz, nc, q, s.n_groups, n)[:, :, :, 0]          # G=1 -> (B,NC,Q,N)
    cg = cc.reshape(bsz, nc, q, s.n_groups, n)[:, :, :, 0]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    dtc = dt.reshape(bsz, nc, q, h)
    a = -jnp.exp(p["a_log"])                                         # (H,) negative
    loga = dtc * a[None, None, None, :]                              # (B,NC,Q,H)
    cum = jnp.cumsum(loga, axis=2)                                   # inclusive

    # --- intra-chunk (quadratic, MXU) ------------------------------------
    cb = jnp.einsum("bnim,bnjm->bnij", cg, bg)                       # (B,NC,Q,Q)
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])   # (B,NC,i,j,H)
    tri = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(tri[None, None, :, :, None], decay, 0.0)
    y_intra = jnp.einsum(
        "bnij,bnijh,bnjh,bnjhp->bnihp",
        cb.astype(jnp.float32), decay, dtc, xh.astype(jnp.float32),
    )

    # --- chunk states + inter-chunk recurrence ----------------------------
    last = cum[:, :, -1:, :]                                         # (B,NC,1,H)
    w_state = jnp.exp(last - cum) * dtc                              # (B,NC,Q,H)
    s_c = jnp.einsum("bnjh,bnjm,bnjhp->bnhmp", w_state, bg.astype(jnp.float32), xh.astype(jnp.float32))
    chunk_decay = jnp.exp(last[:, :, 0, :])                          # (B,NC,H)

    def step(hprev, inp):
        dcy, sc = inp
        hnew = dcy[:, :, None, None] * hprev + sc
        return hnew, hprev

    h0 = jnp.zeros((bsz, h, n, pdim), jnp.float32)
    h_last, h_before = jax.lax.scan(
        step, h0, (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(s_c, 1, 0))
    )
    h_before = jnp.moveaxis(h_before, 0, 1)                          # (B,NC,H,N,P)
    y_inter = jnp.einsum(
        "bnim,bnhmp,bnih->bnihp", cg.astype(jnp.float32), h_before, jnp.exp(cum)
    )

    y = (y_intra + y_inter).reshape(bsz, sl, h, pdim)
    y = y + p["d_skip"][None, None, :, None] * xh.reshape(bsz, sl, h, pdim).astype(jnp.float32)
    y = y.reshape(bsz, sl, s.d_inner).astype(u.dtype)
    y = _gated_norm(p["norm_scale"], y, z)
    out = dense(p["out_proj"], y, in_logical="w_in2", out_logical="w_out2")
    if return_cache:
        conv_tail = jnp.concatenate([x, bb, cc], axis=-1)[:, -(s.d_conv - 1):, :]
        # conv state must be PRE-activation inputs; recompute from raw proj
        zr, xr, br, cr, _ = _split_proj(s, dense(p["in_proj"], u[:, -(s.d_conv - 1):, :]))
        conv_state = jnp.concatenate([xr, br, cr], axis=-1)
        return out, (h_last, conv_state)
    return out


def mamba_decode(p, s: MambaSpec, u, state, conv_state):
    """u: (B,1,D); state: (B,H,N,P) fp32; conv_state: (B,K-1,C).
    Returns (y, new_state, new_conv_state)."""
    bsz = u.shape[0]
    z, x, bb, cc, dt_raw = _split_proj(s, dense(p["in_proj"], u))
    xbc_new = jnp.concatenate([x, bb, cc], axis=-1)                  # (B,1,C)
    window = jnp.concatenate([conv_state, xbc_new], axis=1)          # (B,K,C)
    y_conv = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    y_conv = jax.nn.silu(y_conv)[:, None, :]
    new_conv_state = window[:, 1:, :]

    h, pdim, n = s.n_heads, s.head_dim, s.d_state
    x = y_conv[..., : s.d_inner].reshape(bsz, h, pdim)
    bg = y_conv[..., s.d_inner : s.d_inner + s.n_groups * n].reshape(bsz, n)
    cg = y_conv[..., s.d_inner + s.n_groups * n :].reshape(bsz, n)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])   # (B,H)
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt * a[None, :])                                 # (B,H)
    upd = jnp.einsum("bh,bm,bhp->bhmp", dt, bg.astype(jnp.float32), x.astype(jnp.float32))
    new_state = decay[:, :, None, None] * state + upd
    y = jnp.einsum("bm,bhmp->bhp", cg.astype(jnp.float32), new_state)
    y = y + p["d_skip"][None, :, None] * x.astype(jnp.float32)
    y = y.reshape(bsz, 1, s.d_inner).astype(u.dtype)
    y = _gated_norm(p["norm_scale"], y, z)
    return dense(p["out_proj"], y), new_state, new_conv_state


# ================================================================= RG-LRU ==
@dataclasses.dataclass(frozen=True)
class RGLRUSpec:
    d_model: int
    width: int                 # recurrence width (lru_width)
    n_blocks: int = 10         # block-diagonal gate heads
    d_conv: int = 4
    c: float = 8.0


def init_rglru(key, s: RGLRUSpec, dtype):
    ki, ko, kc, kr, kg = jax.random.split(key, 5)
    w, nb = s.width, s.n_blocks
    bd = w // nb
    return {
        "in_proj": init_dense(ki, s.d_model, 2 * w, dtype),          # x branch + gate branch
        "conv_w": (jax.random.normal(kc, (s.d_conv, w), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "w_r": (jax.random.normal(kr, (nb, bd, bd), jnp.float32) / math.sqrt(bd)).astype(dtype),
        "b_r": jnp.zeros((w,), jnp.float32),
        "w_i": (jax.random.normal(kg, (nb, bd, bd), jnp.float32) / math.sqrt(bd)).astype(dtype),
        "b_i": jnp.zeros((w,), jnp.float32),
        "lam": jnp.linspace(0.9, 0.999, w).astype(jnp.float32),      # Λ init
        "out_proj": init_dense(ko, w, s.d_model, dtype),
    }


def _block_diag(wp, x, nb):
    b, sl, w = x.shape
    xb = x.reshape(b, sl, nb, w // nb)
    return jnp.einsum("bsnk,nkl->bsnl", xb, wp).reshape(b, sl, w)


def _gates(p, s: RGLRUSpec, xc):
    """Recurrence/input gates + log decay. xc: (B,S,W) post-conv."""
    r = jax.nn.sigmoid(_block_diag(p["w_r"], xc, s.n_blocks).astype(jnp.float32) + p["b_r"])
    i = jax.nn.sigmoid(_block_diag(p["w_i"], xc, s.n_blocks).astype(jnp.float32) + p["b_i"])
    log_a = -s.c * jax.nn.softplus(p["lam"]) * r                     # (B,S,W)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-8)) * (i * xc.astype(jnp.float32))
    return a, b


def rglru_prefill(p, s: RGLRUSpec, u, return_cache: bool = False):
    """u: (B,S,D) -> (B,S,D). Parallel via associative scan."""
    xz = dense(p["in_proj"], u)
    xb, gate = xz[..., : s.width], xz[..., s.width :]
    xc = _causal_conv(xb, p["conv_w"], p["conv_b"])
    xc = shard(xc, "batch", "seq", "act_d_ff")
    a, bvec = _gates(p, s, xc)

    def combine(lhs, rhs):
        al, bl = lhs
        ar, br = rhs
        return al * ar, bl * ar + br

    _acc_a, acc_b = jax.lax.associative_scan(combine, (a, bvec), axis=1)
    h = acc_b                                                        # h_t with h_0 = 0
    y = (h.astype(u.dtype) * jax.nn.gelu(gate, approximate=True))
    out = dense(p["out_proj"], y, in_logical="w_in2", out_logical="w_out2")
    if return_cache:
        conv_state = xb[:, -(s.d_conv - 1):, :]
        return out, (h[:, -1, :], conv_state)
    return out


def rglru_decode(p, s: RGLRUSpec, u, hstate, conv_state):
    """u: (B,1,D); hstate: (B,W) fp32; conv_state: (B,K-1,W)."""
    xz = dense(p["in_proj"], u)
    xb, gate = xz[..., : s.width], xz[..., s.width :]
    window = jnp.concatenate([conv_state, xb], axis=1)               # (B,K,W)
    xc = jax.nn.silu(jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"])[:, None, :]
    new_conv = window[:, 1:, :]
    a, bvec = _gates(p, s, xc)
    hnew = a[:, 0] * hstate + bvec[:, 0]
    y = (hnew[:, None, :].astype(u.dtype) * jax.nn.gelu(gate, approximate=True))
    return dense(p["out_proj"], y), hnew, new_conv
