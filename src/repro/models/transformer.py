"""Unified decoder-LM assembly: pattern-grouped blocks under lax.scan.

The repeating ``block_pattern`` (e.g. 5x local + 1x global for gemma3,
(rglru, rglru, attn_local) for recurrentgemma) forms one *group*; parameters
of all groups are stacked on a leading axis and the stack is scanned —
keeping the lowered HLO one-group-sized regardless of depth (80-layer
qwen1.5-110b lowers the same program as an 8-layer toy).

Local attention uses ring-buffer KV caches of exactly ``window`` slots
(semantically exact for decode; memory-optimal for long_500k) — a TPU
adaptation choice, see DESIGN.md §6.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import shard
from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models import ssm as SSM


# ------------------------------------------------------------ block specs --
def _attn_spec(cfg: ModelConfig, local: bool) -> L.AttnSpec:
    return L.AttnSpec(
        d_model=cfg.d_model,
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim,
        qkv_bias=cfg.qkv_bias,
        window=cfg.window if local else 0,
        rope_theta=cfg.rope_theta,
        impl=cfg.attn_impl,
        q_chunk=cfg.attn_q_chunk,
        kv_chunk=cfg.attn_kv_chunk,
        unroll_inner=cfg.unroll_layers,
    )


def _mla_spec(cfg: ModelConfig) -> MLA.MLASpec:
    return MLA.MLASpec(
        d_model=cfg.d_model,
        n_heads=cfg.n_heads,
        q_lora_rank=cfg.q_lora_rank,
        kv_lora_rank=cfg.kv_lora_rank,
        qk_nope_head_dim=cfg.qk_nope_head_dim,
        qk_rope_head_dim=cfg.qk_rope_head_dim,
        v_head_dim=cfg.v_head_dim,
        rope_theta=cfg.rope_theta,
        norm_eps=cfg.norm_eps,
        impl=cfg.attn_impl,
        q_chunk=cfg.attn_q_chunk,
        kv_chunk=cfg.attn_kv_chunk,
        unroll_inner=cfg.unroll_layers,
    )


def _moe_spec(cfg: ModelConfig) -> MOE.MoESpec:
    return MOE.MoESpec(
        d_model=cfg.d_model,
        n_experts=cfg.n_experts,
        top_k=cfg.top_k,
        expert_d_ff=cfg.expert_d_ff,
        n_shared_experts=cfg.n_shared_experts,
        shared_d_ff=cfg.shared_d_ff,
        capacity_factor=cfg.capacity_factor,
        moe_group=cfg.moe_group,
        act=cfg.act,
    )


def _mamba_spec(cfg: ModelConfig) -> SSM.MambaSpec:
    return SSM.MambaSpec(
        d_model=cfg.d_model,
        d_state=cfg.ssm_state,
        head_dim=cfg.ssm_head_dim,
        expand=cfg.ssm_expand,
        d_conv=cfg.ssm_conv,
        chunk=cfg.ssm_chunk,
    )


def _rglru_spec(cfg: ModelConfig) -> SSM.RGLRUSpec:
    return SSM.RGLRUSpec(
        d_model=cfg.d_model,
        width=cfg.rglru_width,
        n_blocks=cfg.rglru_blocks,
        d_conv=cfg.ssm_conv,
    )


# ------------------------------------------------------------- block init --
def init_block(key, cfg: ModelConfig, kind: str):
    dt = cfg.param_dtype
    k1, k2, k3 = jax.random.split(key, 3)
    p: dict[str, Any] = {"norm1": L.init_rms_norm(cfg.d_model, dt)}
    if kind in ("attn_global", "attn_local"):
        p["attn"] = L.init_attention(k1, _attn_spec(cfg, kind == "attn_local"), dt)
    elif kind == "mla":
        p["attn"] = MLA.init_mla(k1, _mla_spec(cfg), dt)
    elif kind == "moe":
        p["attn"] = L.init_attention(k1, _attn_spec(cfg, False), dt)
        p["norm2"] = L.init_rms_norm(cfg.d_model, dt)
        p["moe"] = MOE.init_moe(k2, _moe_spec(cfg), dt)
        return p
    elif kind == "mamba2":
        p["mixer"] = SSM.init_mamba(k1, _mamba_spec(cfg), dt)
        return p  # mamba2 stack has no separate FFN (d_ff == 0)
    elif kind == "rglru":
        p["mixer"] = SSM.init_rglru(k1, _rglru_spec(cfg), dt)
    else:
        raise ValueError(kind)
    if cfg.d_ff:
        p["norm2"] = L.init_rms_norm(cfg.d_model, dt)
        p["ffn"] = L.init_ffn(k3, cfg.d_model, cfg.d_ff, dt, cfg.act)
    return p


# ------------------------------------------------------ full-seq block fwd --
def _pad_seq(t, smax: int):
    """Pad a (B, S, ...) cache tensor out to smax slots."""
    s = t.shape[1]
    if smax <= s:
        return t
    pad = [(0, 0)] * t.ndim
    pad[1] = (0, smax - s)
    return jnp.pad(t, pad)


def block_fwd(
    p, cfg: ModelConfig, kind: str, x, positions, want_cache: bool, smax: int = 0
):
    """Train (want_cache=False) / prefill (True) forward of one block.
    Returns (x, cache_or_None, aux_loss). smax sizes the decode cache
    (>= S so decode can continue past the prefill length)."""
    aux = jnp.zeros((), jnp.float32)
    h = L.rms_norm(p["norm1"], x, cfg.norm_eps)
    cache = None
    if kind in ("attn_global", "attn_local"):
        spec = _attn_spec(cfg, kind == "attn_local")
        if want_cache:
            y, (k, v) = L.mha(p["attn"], spec, h, positions, return_kv=True)
            cache = _ring_cache_from_prefill(cfg, kind, k, v) if kind == "attn_local" \
                else {"k": _pad_seq(k, smax), "v": _pad_seq(v, smax)}
        else:
            y = L.mha(p["attn"], spec, h, positions)
    elif kind == "mla":
        sl = x.shape[1]
        mask = L._attn_mask(sl, sl, 0, 0, True)
        if want_cache:
            y, (ckv, kr) = MLA.mla_prefill(p["attn"], _mla_spec(cfg), h, positions, mask, True)
            cache = {"ckv": _pad_seq(ckv, smax), "kr": _pad_seq(kr, smax)}
        else:
            y = MLA.mla_prefill(p["attn"], _mla_spec(cfg), h, positions, mask)
    elif kind == "moe":
        spec = _attn_spec(cfg, False)
        if want_cache:
            y, (k, v) = L.mha(p["attn"], spec, h, positions, return_kv=True)
            cache = {"k": _pad_seq(k, smax), "v": _pad_seq(v, smax)}
        else:
            y = L.mha(p["attn"], spec, h, positions)
        x = x + y
        h2 = L.rms_norm(p["norm2"], x, cfg.norm_eps)
        y2, aux = MOE.moe_ffn(p["moe"], _moe_spec(cfg), h2)
        return x + y2, cache, aux
    elif kind == "mamba2":
        if want_cache:
            y, (state, conv) = SSM.mamba_prefill(p["mixer"], _mamba_spec(cfg), h, True)
            cache = {"state": state, "conv": conv}
        else:
            y = SSM.mamba_prefill(p["mixer"], _mamba_spec(cfg), h)
        return x + y, cache, aux
    elif kind == "rglru":
        if want_cache:
            y, (state, conv) = SSM.rglru_prefill(p["mixer"], _rglru_spec(cfg), h, True)
            cache = {"state": state, "conv": conv}
        else:
            y = SSM.rglru_prefill(p["mixer"], _rglru_spec(cfg), h)
    else:
        raise ValueError(kind)
    x = x + y
    if "ffn" in p:
        x = x + L.ffn(p["ffn"], L.rms_norm(p["norm2"], x, cfg.norm_eps), cfg.act)
    return x, cache, aux


def _ring_cache_from_prefill(cfg: ModelConfig, kind: str, k, v):
    """Convert full prefill K/V into a window-sized ring buffer."""
    w = cfg.window
    b, sl, kh, hd = k.shape
    if sl >= w:
        absi = jnp.arange(sl - w, sl)
        slots = absi % w
        rk = jnp.zeros((b, w, kh, hd), k.dtype).at[:, slots].set(k[:, sl - w :])
        rv = jnp.zeros((b, w, kh, hd), v.dtype).at[:, slots].set(v[:, sl - w :])
        pos_idx = jnp.zeros((w,), jnp.int32).at[slots].set(absi.astype(jnp.int32))
    else:
        pad = w - sl
        rk = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        rv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pos_idx = jnp.concatenate(
            [jnp.arange(sl, dtype=jnp.int32), jnp.full((pad,), -1, jnp.int32)]
        )
    return {"k": rk, "v": rv, "pos_idx": pos_idx}


# -------------------------------------------------------------- decode fwd --
def block_decode(p, cfg: ModelConfig, kind: str, x, cache, pos):
    """One-token decode. Returns (x, new_cache)."""
    h = L.rms_norm(p["norm1"], x, cfg.norm_eps)
    if kind in ("attn_global", "moe"):
        spec = _attn_spec(cfg, False)
        y, ck, cv = L.mha_decode(p["attn"], spec, h, cache["k"], cache["v"], pos)
        cache = {"k": ck, "v": cv}
    elif kind == "attn_local":
        y, cache = _local_decode(p["attn"], cfg, h, cache, pos)
    elif kind == "mla":
        y, ckv, kr = MLA.mla_decode(p["attn"], _mla_spec(cfg), h, cache["ckv"], cache["kr"], pos)
        cache = {"ckv": ckv, "kr": kr}
    elif kind == "mamba2":
        y, st, cv = SSM.mamba_decode(p["mixer"], _mamba_spec(cfg), h, cache["state"], cache["conv"])
        cache = {"state": st, "conv": cv}
    elif kind == "rglru":
        y, st, cv = SSM.rglru_decode(p["mixer"], _rglru_spec(cfg), h, cache["state"], cache["conv"])
        cache = {"state": st, "conv": cv}
    else:
        raise ValueError(kind)
    if kind == "moe":
        x = x + y
        h2 = L.rms_norm(p["norm2"], x, cfg.norm_eps)
        y2, _ = MOE.moe_ffn(p["moe"], _moe_spec(cfg), h2)
        return x + y2, cache
    x = x + y
    if "ffn" in p:
        x = x + L.ffn(p["ffn"], L.rms_norm(p["norm2"], x, cfg.norm_eps), cfg.act)
    return x, cache


def _local_decode(p, cfg: ModelConfig, h, cache, pos):
    """Ring-buffer sliding-window decode."""
    spec = _attn_spec(cfg, True)
    b, one, _ = h.shape
    w = cfg.window
    q = L.dense(p["wq"], h).reshape(b, one, spec.n_heads, spec.head_dim)
    k = L.dense(p["wk"], h).reshape(b, one, spec.n_kv_heads, spec.head_dim)
    v = L.dense(p["wv"], h).reshape(b, one, spec.n_kv_heads, spec.head_dim)
    pvec = jnp.full((b, one), pos, jnp.int32)
    q = L.apply_rope(q, pvec, spec.rope_theta)
    k = L.apply_rope(k, pvec, spec.rope_theta)
    slot = jax.lax.rem(pos, w)
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], L._kv_quant(k, cache["k"].dtype), slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], L._kv_quant(v, cache["v"].dtype), slot, axis=1)
    pidx = jax.lax.dynamic_update_slice_in_dim(
        cache["pos_idx"], jnp.full((1,), pos, jnp.int32), slot, axis=0
    )
    ok = (pidx <= pos) & (pidx > pos - w) & (pidx >= 0)              # (w,)
    rep = spec.n_heads // spec.n_kv_heads
    qg = q.reshape(b, one, spec.n_kv_heads, rep, spec.head_dim)
    scores = jnp.einsum(
        "bqkrh,bskh->bkrqs", qg.astype(jnp.float32), L._kv_dequant(ck).astype(jnp.float32))
    scores = scores / (spec.head_dim ** 0.5)
    scores = jnp.where(ok[None, None, None, None, :], scores, -jnp.inf)
    attn = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum(
        "bkrqs,bskh->bqkrh", attn, L._kv_dequant(cv).astype(jnp.float32)
    ).astype(h.dtype).reshape(b, one, spec.n_heads * spec.head_dim)
    y = L.dense(p["wo"], o, in_logical="w_in2", out_logical="w_out2")
    return y, {"k": ck, "v": cv, "pos_idx": pidx}


# ----------------------------------------------------------- cache specs ---
def block_cache_spec(cfg: ModelConfig, kind: str, batch: int, smax: int, dtype):
    """ShapeDtypeStruct pytree for one block's decode cache."""
    hd = cfg.resolved_head_dim
    kv_dt = jnp.int8 if cfg.kv_cache_quant else dtype
    if kind in ("attn_global", "moe"):
        shp = (batch, smax, cfg.n_kv_heads, hd)
        return {"k": jax.ShapeDtypeStruct(shp, kv_dt), "v": jax.ShapeDtypeStruct(shp, kv_dt)}
    if kind == "attn_local":
        w = cfg.window
        shp = (batch, w, cfg.n_kv_heads, hd)
        return {
            "k": jax.ShapeDtypeStruct(shp, kv_dt),
            "v": jax.ShapeDtypeStruct(shp, kv_dt),
            "pos_idx": jax.ShapeDtypeStruct((w,), jnp.int32),
        }
    if kind == "mla":
        return {
            "ckv": jax.ShapeDtypeStruct((batch, smax, cfg.kv_lora_rank), dtype),
            "kr": jax.ShapeDtypeStruct((batch, smax, cfg.qk_rope_head_dim), dtype),
        }
    if kind == "mamba2":
        s = _mamba_spec(cfg)
        return {
            "state": jax.ShapeDtypeStruct((batch, s.n_heads, s.d_state, s.head_dim), jnp.float32),
            "conv": jax.ShapeDtypeStruct((batch, s.d_conv - 1, s.conv_channels), cfg.param_dtype),
        }
    if kind == "rglru":
        s = _rglru_spec(cfg)
        return {
            "state": jax.ShapeDtypeStruct((batch, s.width), jnp.float32),
            "conv": jax.ShapeDtypeStruct((batch, s.d_conv - 1, s.width), cfg.param_dtype),
        }
    raise ValueError(kind)


# ------------------------------------------------------------ full model ---
def init_decoder(key, cfg: ModelConfig):
    cfg.validate()
    keys = jax.random.split(key, cfg.n_layers + 3)
    params: dict[str, Any] = {
        "embed": L.init_embedding(keys[0], cfg.padded_vocab, cfg.d_model, cfg.param_dtype),
        "final_norm": L.init_rms_norm(cfg.d_model, cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_dense(keys[1], cfg.d_model, cfg.padded_vocab, cfg.param_dtype)
    # stacked group params
    groups = []
    ki = 2
    for g in range(cfg.n_groups):
        group = tuple(
            init_block(keys[ki + g * cfg.pattern_len + i], cfg, kind)
            for i, kind in enumerate(cfg.block_pattern)
        )
        groups.append(group)
    if groups:
        params["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *groups)
    params["tail"] = tuple(
        init_block(keys[ki + cfg.n_groups * cfg.pattern_len + i], cfg, kind)
        for i, kind in enumerate(cfg.tail_blocks)
    )
    return params


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def decoder_hidden(params, cfg: ModelConfig, x, positions):
    """Training forward through all blocks. Returns (hidden, aux_loss)."""

    def group_body(carry, gp):
        x, aux = carry
        # SP on the scan carry: the remat-saved residual buffer shards over
        # the model axis between groups (gathered lazily inside the block)
        x = shard(x, "batch", "residual_seq", None)
        for i, kind in enumerate(cfg.block_pattern):
            x, _, a = block_fwd(gp[i], cfg, kind, x, positions, want_cache=False)
            aux = aux + a
        x = shard(x, "batch", "residual_seq", None)
        return (x, aux), None

    aux0 = jnp.zeros((), jnp.float32)
    if cfg.n_groups:
        if cfg.unroll_layers:
            for gi in range(cfg.n_groups):
                gp = jax.tree.map(lambda p: p[gi], params["blocks"])
                (x, aux0), _ = _remat(cfg, group_body)((x, aux0), gp)
        else:
            (x, aux0), _ = jax.lax.scan(_remat(cfg, group_body), (x, aux0), params["blocks"])
    for i, kind in enumerate(cfg.tail_blocks):
        x, _, a = block_fwd(params["tail"][i], cfg, kind, x, positions, want_cache=False)
        aux0 = aux0 + a
    return L.rms_norm(params["final_norm"], x, cfg.norm_eps), aux0


def decoder_prefill(params, cfg: ModelConfig, x, positions, smax: int = 0):
    """Prefill forward; returns (hidden, caches) where caches =
    (scanned: tuple-per-pattern-pos with leading G, tail: tuple).
    smax >= S sizes the KV caches for continued decoding."""
    smax = max(smax, x.shape[1])

    def group_body(x, gp):
        caches = []
        for i, kind in enumerate(cfg.block_pattern):
            x, c, _ = block_fwd(gp[i], cfg, kind, x, positions, want_cache=True, smax=smax)
            caches.append(c)
        return x, tuple(caches)

    scanned = None
    if cfg.n_groups:
        if cfg.unroll_layers:
            outs = []
            for gi in range(cfg.n_groups):
                gp = jax.tree.map(lambda p: p[gi], params["blocks"])
                x, c = _remat(cfg, group_body)(x, gp)
                outs.append(c)
            scanned = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        else:
            x, scanned = jax.lax.scan(_remat(cfg, group_body), x, params["blocks"])
    tail = []
    for i, kind in enumerate(cfg.tail_blocks):
        x, c, _ = block_fwd(params["tail"][i], cfg, kind, x, positions, want_cache=True, smax=smax)
        tail.append(c)
    return L.rms_norm(params["final_norm"], x, cfg.norm_eps), (scanned, tuple(tail))


def decoder_decode(params, cfg: ModelConfig, caches, x, pos):
    """One-token decode; returns (hidden, new_caches)."""
    scanned, tail = caches

    def group_body(x, inp):
        gp, gc = inp
        new = []
        for i, kind in enumerate(cfg.block_pattern):
            x, nc = block_decode(gp[i], cfg, kind, x, gc[i], pos)
            new.append(nc)
        return x, tuple(new)

    new_scanned = None
    if cfg.n_groups:
        if cfg.unroll_layers:
            outs = []
            for gi in range(cfg.n_groups):
                gp = jax.tree.map(lambda p: p[gi], params["blocks"])
                gc = jax.tree.map(lambda c: c[gi], scanned)
                x, nc = group_body(x, (gp, gc))
                outs.append(nc)
            new_scanned = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        else:
            x, new_scanned = jax.lax.scan(group_body, x, (params["blocks"], scanned))
    new_tail = []
    for i, kind in enumerate(cfg.tail_blocks):
        x, nc = block_decode(params["tail"][i], cfg, kind, x, tail[i], pos)
        new_tail.append(nc)
    return L.rms_norm(params["final_norm"], x, cfg.norm_eps), (new_scanned, tuple(new_tail))


def logits_from_hidden(params, cfg: ModelConfig, hidden):
    if cfg.tie_embeddings:
        return L.unembed(params["embed"], hidden)
    return shard(L.dense(params["lm_head"], hidden), "batch", "seq", "act_vocab")


def decoder_cache_specs(cfg: ModelConfig, batch: int, smax: int):
    dt = cfg.param_dtype
    scanned = None
    if cfg.n_groups:
        per_pos = tuple(
            block_cache_spec(cfg, kind, batch, smax, dt) for kind in cfg.block_pattern
        )
        scanned = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((cfg.n_groups, *s.shape), s.dtype), per_pos
        )
    tail = tuple(block_cache_spec(cfg, kind, batch, smax, dt) for kind in cfg.tail_blocks)
    return (scanned, tail)
