"""Shared model building blocks (pure functions over param dicts).

Conventions:
  * params are nested dicts of jnp arrays; leading "G" axis on scan-stacked
    block params is added by transformer.py, not here.
  * activations bf16 (config dtype); norms/softmax/rope math in fp32.
  * every matmul annotates logical sharding via repro.distributed.shard.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed import shard


def init_dense(key, d_in: int, d_out: int, dtype, bias: bool = False, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
    p = {"w": w.astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x, in_logical: str = "w_in", out_logical: str = "w_out"):
    _ = (in_logical, out_logical)
    w = p["w"]
    if "w_scale" in p:  # w8a16 serving weights: int8 + per-tensor scale
        w = w.astype(x.dtype) * p["w_scale"].astype(x.dtype)
    y = x @ w
    if "b" in p:
        y = y + p["b"]
    return y


def raw_weight(p, dtype):
    """Materialize a dense weight in compute dtype (dequantizing w8)."""
    w = p["w"]
    if "w_scale" in p:
        return w.astype(dtype) * p["w_scale"].astype(dtype)
    return w.astype(dtype)


def quantize_dense_weights(params):
    """Post-init transform: every 2-D dense 'w' becomes int8 + per-tensor
    scale (w8a16 serving mode). Norm scales, biases, embeddings and SSM
    state params stay in their original dtype."""

    def walk(node):
        if isinstance(node, dict):
            if "w" in node and hasattr(node["w"], "ndim") and node["w"].ndim in (2, 3) \
                    and node["w"].dtype != jnp.int8:
                w = node["w"].astype(jnp.float32)
                if w.ndim == 3:   # scan-stacked (G, din, dout): per-layer scale
                    scale = jnp.maximum(jnp.max(jnp.abs(w), axis=(1, 2), keepdims=True), 1e-8) / 127.0
                else:
                    scale = jnp.maximum(jnp.max(jnp.abs(w)), 1e-8) / 127.0
                q = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
                out = dict(node)
                out["w"] = q
                out["w_scale"] = scale.astype(jnp.float32)
                return out
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return node

    return walk(params)


def init_rms_norm(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(p, x, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


# ----------------------------------------------------------------- rotary --
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                         # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                      # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., : hd // 2], xf[..., hd // 2 :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------- ffn ---
def init_ffn(key, d_model: int, d_ff: int, dtype, act: str = "silu"):
    k1, k2, k3 = jax.random.split(key, 3)
    _ = act  # activation is a config property, not a param (pytree purity)
    return {
        "gate": init_dense(k1, d_model, d_ff, dtype),
        "up": init_dense(k2, d_model, d_ff, dtype),
        "down": init_dense(k3, d_ff, d_model, dtype),
    }


def _act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": lambda x: jax.nn.gelu(x, approximate=True)}[name]


def ffn(p, x, act: str = "silu"):
    g = dense(p["gate"], x)
    u = dense(p["up"], x)
    g = shard(g, "batch", "seq", "act_d_ff")
    h = _act_fn(act)(g) * u
    y = dense(p["down"], h, in_logical="w_in2", out_logical="w_out2")
    return shard(y, "batch", "residual_seq", None)


# ------------------------------------------------------------- attention ---
@dataclasses.dataclass(frozen=True)
class AttnSpec:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    window: int = 0            # 0 = global causal; >0 = sliding window
    causal: bool = True
    rope_theta: float = 1e4
    impl: str = "dense"        # "dense" | "chunked" (flash-style, O(S*C) mem)
    q_chunk: int = 2048
    kv_chunk: int = 1024
    unroll_inner: bool = False  # python inner loop (dry-run exact costing)


def init_attention(key, s: AttnSpec, dtype):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": init_dense(kq, s.d_model, s.n_heads * s.head_dim, dtype, bias=s.qkv_bias),
        "wk": init_dense(kk, s.d_model, s.n_kv_heads * s.head_dim, dtype, bias=s.qkv_bias),
        "wv": init_dense(kv, s.d_model, s.n_kv_heads * s.head_dim, dtype, bias=s.qkv_bias),
        "wo": init_dense(ko, s.n_heads * s.head_dim, s.d_model, dtype),
    }


def chunked_attention(
    qg: jnp.ndarray,            # (B, Sq, K, R, hd) grouped queries
    k: jnp.ndarray,             # (B, Skv, K, hd)
    v: jnp.ndarray,             # (B, Skv, K, hd)
    *,
    causal: bool,
    window: int,
    mask_offset: int,
    q_chunk: int,
    kv_chunk: int,
    scale: float,
    unroll_inner: bool = False,
) -> jnp.ndarray:
    """Flash-style double-chunked attention: O(Sq * kv_chunk) live memory.

    TPU adaptation of blockwise attention: query chunks are a Python loop
    (static banded/causal ranges skip fully-masked KV chunks — the win for
    sliding-window layers); KV chunks run under lax.scan with running
    max/denominator in fp32. Bit-compatible with the dense path (same
    softmax), validated by tests/test_chunked_attn.py.
    """
    b, sq, kh, rep, hd = qg.shape
    skv = k.shape[1]
    vd = v.shape[-1]            # v head dim may differ from qk (MLA)
    cq = min(q_chunk, sq)
    ck = min(kv_chunk, skv)
    assert sq % cq == 0 and skv % ck == 0, (sq, cq, skv, ck)
    n_kv_chunks = skv // ck
    k_chunks = k.reshape(b, n_kv_chunks, ck, kh, hd)
    v_chunks = v.reshape(b, n_kv_chunks, ck, kh, vd)

    outs = []
    for qi in range(sq // cq):
        q_lo = qi * cq
        q_abs = q_lo + mask_offset                        # kv-pos of chunk start
        # static KV range for this query chunk
        j_hi = n_kv_chunks if not causal else min(
            n_kv_chunks, (q_abs + cq - 1) // ck + 1)
        j_lo = 0 if window <= 0 else max(0, (q_abs - window + 1) // ck)
        j_lo = min(j_lo, max(j_hi - 1, 0))
        qc = qg[:, q_lo : q_lo + cq].astype(jnp.float32)  # (B,Cq,K,R,hd)

        qpos = (jnp.arange(cq) + q_abs)[None, :]          # (1, Cq)

        def kv_step(carry, inp):
            m, l, acc = carry
            kc, vc, j = inp
            kpos = (j * ck + jnp.arange(ck))[:, None].T   # (1, Ck)
            s = jnp.einsum("bqkrh,bskh->bkrqs", qc, kc.astype(jnp.float32)) * scale
            ok = jnp.ones((cq, ck), bool)
            if causal:
                ok = ok & (kpos <= qpos.T)
            if window > 0:
                ok = ok & (kpos > qpos.T - window)
            s = jnp.where(ok[None, None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # guard: fully-masked rows keep m = -inf; exp(-inf - -inf) -> nan
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkrqs,bskh->bkrqh", p, vc.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kh, rep, cq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, kh, rep, cq), jnp.float32)
        a0 = jnp.zeros((b, kh, rep, cq, vd), jnp.float32)
        idxs = jnp.arange(j_lo, j_hi)
        kc_sel = k_chunks[:, j_lo:j_hi]
        vc_sel = v_chunks[:, j_lo:j_hi]
        if unroll_inner:
            carry = (m0, l0, a0)
            for t, j in enumerate(range(j_lo, j_hi)):
                carry, _ = kv_step(
                    carry, (kc_sel[:, t], vc_sel[:, t], jnp.int32(j)))
            m, l, acc = carry
        else:
            (m, l, acc), _ = jax.lax.scan(
                kv_step, (m0, l0, a0),
                (jnp.moveaxis(kc_sel, 1, 0), jnp.moveaxis(vc_sel, 1, 0), idxs),
            )
        o = acc / jnp.maximum(l[..., None], 1e-30)        # (B,K,R,Cq,vd)
        # downcast at the chunk boundary: everything downstream (wo matmul,
        # residual, collectives) must run in the compute dtype, not fp32
        outs.append(jnp.moveaxis(o, 3, 1).astype(v.dtype))  # (B,Cq,K,R,vd)
    return jnp.concatenate(outs, axis=1)


def _attn_mask(sq: int, skv: int, offset: int, window: int, causal: bool) -> jnp.ndarray:
    """(sq, skv) additive mask in fp32. offset = kv index of query 0."""
    qi = jnp.arange(sq)[:, None] + offset
    ki = jnp.arange(skv)[None, :]
    ok = jnp.ones((sq, skv), bool)
    if causal:
        ok = ok & (ki <= qi)
    if window > 0:
        ok = ok & (ki > qi - window)
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)


def mha(
    p,
    s: AttnSpec,
    x: jnp.ndarray,                  # (B, S, D)
    positions: jnp.ndarray,          # (B, S)
    kv_x: jnp.ndarray | None = None,  # cross-attention source
    kv_positions: jnp.ndarray | None = None,
    mask_offset: int = 0,
    use_rope: bool = True,
    return_kv: bool = False,
):
    """Full-sequence attention (train / prefill / encoder / cross)."""
    b, sq, _ = x.shape
    src = x if kv_x is None else kv_x
    skv = src.shape[1]
    q = dense(p["wq"], x).reshape(b, sq, s.n_heads, s.head_dim)
    k = dense(p["wk"], src).reshape(b, skv, s.n_kv_heads, s.head_dim)
    v = dense(p["wv"], src).reshape(b, skv, s.n_kv_heads, s.head_dim)
    if use_rope:
        q = apply_rope(q, positions, s.rope_theta)
        kpos = positions if kv_positions is None else kv_positions
        k = apply_rope(k, kpos, s.rope_theta)
    q = shard(q, "batch", "seq", "act_heads", None)
    k = shard(k, "batch", "kv_seq", "act_heads", None)
    v = shard(v, "batch", "kv_seq", "act_heads", None)

    rep = s.n_heads // s.n_kv_heads
    qg = q.reshape(b, sq, s.n_kv_heads, rep, s.head_dim)
    if s.impl == "chunked" and kv_x is None:
        o = chunked_attention(
            qg, k, v,
            causal=s.causal, window=s.window, mask_offset=mask_offset,
            q_chunk=s.q_chunk, kv_chunk=s.kv_chunk,
            scale=1.0 / math.sqrt(s.head_dim), unroll_inner=s.unroll_inner,
        ).astype(x.dtype).reshape(b, sq, s.n_heads * s.head_dim)
    else:
        scores = jnp.einsum("bqkrh,bskh->bkrqs", qg, k).astype(jnp.float32)
        scores = scores / math.sqrt(s.head_dim)
        if kv_x is None:  # self-attention mask
            scores = scores + _attn_mask(sq, skv, mask_offset, s.window, s.causal)
        w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        o = jnp.einsum("bkrqs,bskh->bqkrh", w, v).reshape(b, sq, s.n_heads * s.head_dim)
    o = shard(o, "batch", "seq", "act_heads")
    y = dense(p["wo"], o, in_logical="w_in2", out_logical="w_out2")
    y = shard(y, "batch", "residual_seq", None)
    if return_kv:
        return y, (k, v)
    return y


# symmetric fixed-point scale for int8 KV quantization (kv8 serving mode);
# post-rope keys and values are O(1), so +-8.0 full-scale keeps headroom.
KV_SCALE = 8.0 / 127.0


def _kv_quant(x: jnp.ndarray, cache_dtype) -> jnp.ndarray:
    if cache_dtype == jnp.int8:
        return jnp.clip(jnp.round(x.astype(jnp.float32) / KV_SCALE), -127, 127).astype(jnp.int8)
    return x.astype(cache_dtype)


def _kv_dequant(x: jnp.ndarray) -> jnp.ndarray:
    if x.dtype == jnp.int8:
        return x.astype(jnp.float32) * KV_SCALE
    return x


def mha_decode(
    p,
    s: AttnSpec,
    x: jnp.ndarray,            # (B, 1, D) new token(s)
    cache_k: jnp.ndarray,      # (B, S_max, K, hd)
    cache_v: jnp.ndarray,
    pos: jnp.ndarray,          # scalar int32: index of the new token
    use_rope: bool = True,
):
    """Single-token decode against a KV cache. Returns (y, new_k, new_v)."""
    b, one, _ = x.shape
    smax = cache_k.shape[1]
    q = dense(p["wq"], x).reshape(b, one, s.n_heads, s.head_dim)
    k = dense(p["wk"], x).reshape(b, one, s.n_kv_heads, s.head_dim)
    v = dense(p["wv"], x).reshape(b, one, s.n_kv_heads, s.head_dim)
    if use_rope:
        pvec = jnp.full((b, one), pos, jnp.int32)
        q = apply_rope(q, pvec, s.rope_theta)
        k = apply_rope(k, pvec, s.rope_theta)
    ck = jax.lax.dynamic_update_slice_in_dim(cache_k, _kv_quant(k, cache_k.dtype), pos, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache_v, _kv_quant(v, cache_v.dtype), pos, axis=1)
    ck = shard(ck, "batch", "kv_seq", "act_heads", None)
    cv = shard(cv, "batch", "kv_seq", "act_heads", None)

    rep = s.n_heads // s.n_kv_heads
    qg = q.reshape(b, one, s.n_kv_heads, rep, s.head_dim)
    scores = jnp.einsum(
        "bqkrh,bskh->bkrqs", qg.astype(jnp.float32), _kv_dequant(ck).astype(jnp.float32)
    )
    scores = scores / math.sqrt(s.head_dim)
    ki = jnp.arange(smax)[None, None, None, None, :]
    ok = ki <= pos
    if s.window > 0:
        ok = ok & (ki > pos - s.window)
    scores = jnp.where(ok, scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o = jnp.einsum(
        "bkrqs,bskh->bqkrh", w.astype(jnp.float32), _kv_dequant(cv).astype(jnp.float32)
    ).astype(x.dtype).reshape(b, one, s.n_heads * s.head_dim)
    y = dense(p["wo"], o, in_logical="w_in2", out_logical="w_out2")
    return y, ck, cv


# ------------------------------------------------------------- embedding ---
def init_embedding(key, vocab: int, d_model: int, dtype):
    w = jax.random.normal(key, (vocab, d_model), jnp.float32) * 0.02
    return {"table": w.astype(dtype)}


def embed(p, tokens: jnp.ndarray) -> jnp.ndarray:
    y = jnp.take(p["table"], tokens, axis=0)
    return shard(y, "batch", "seq", None)


def unembed(p, x: jnp.ndarray) -> jnp.ndarray:
    logits = x @ p["table"].T
    return shard(logits, "batch", "seq", "act_vocab")
