"""Whisper-style encoder–decoder backbone (conv/audio frontend stubbed).

Encoder: bidirectional self-attention blocks over precomputed frame
embeddings (the stub input). Decoder: causal self-attention + cross-attention
blocks. Both stacks scan over layers like transformer.py.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L


def _spec(cfg: ModelConfig, causal: bool) -> L.AttnSpec:
    return L.AttnSpec(
        d_model=cfg.d_model,
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim,
        qkv_bias=cfg.qkv_bias,
        causal=causal,
        rope_theta=cfg.rope_theta,
    )


def _init_enc_layer(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {
        "norm1": L.init_rms_norm(cfg.d_model, cfg.param_dtype),
        "attn": L.init_attention(k1, _spec(cfg, False), cfg.param_dtype),
        "norm2": L.init_rms_norm(cfg.d_model, cfg.param_dtype),
        "ffn": L.init_ffn(k2, cfg.d_model, cfg.d_ff, cfg.param_dtype, cfg.act),
    }


def _init_dec_layer(key, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": L.init_rms_norm(cfg.d_model, cfg.param_dtype),
        "self_attn": L.init_attention(k1, _spec(cfg, True), cfg.param_dtype),
        "norm_x": L.init_rms_norm(cfg.d_model, cfg.param_dtype),
        "cross_attn": L.init_attention(k2, _spec(cfg, False), cfg.param_dtype),
        "norm2": L.init_rms_norm(cfg.d_model, cfg.param_dtype),
        "ffn": L.init_ffn(k3, cfg.d_model, cfg.d_ff, cfg.param_dtype, cfg.act),
    }


def init_encdec(key, cfg: ModelConfig):
    keys = jax.random.split(key, cfg.n_enc_layers + cfg.n_layers + 2)
    enc = [_init_enc_layer(keys[i], cfg) for i in range(cfg.n_enc_layers)]
    dec = [_init_dec_layer(keys[cfg.n_enc_layers + i], cfg) for i in range(cfg.n_layers)]
    return {
        "embed": L.init_embedding(keys[-2], cfg.padded_vocab, cfg.d_model, cfg.param_dtype),
        "enc_blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
        "dec_blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *dec),
        "enc_norm": L.init_rms_norm(cfg.d_model, cfg.param_dtype),
        "final_norm": L.init_rms_norm(cfg.d_model, cfg.param_dtype),
    }


def encode(params, cfg: ModelConfig, frames):
    """frames: (B, S_enc, D) precomputed frame embeddings (frontend stub)."""
    b, s_enc, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(s_enc), (b, s_enc))

    def body(x, lp):
        h = L.rms_norm(lp["norm1"], x, cfg.norm_eps)
        x = x + L.mha(lp["attn"], _spec(cfg, False), h, positions)
        x = x + L.ffn(lp["ffn"], L.rms_norm(lp["norm2"], x, cfg.norm_eps), cfg.act)
        return x, None

    x = frames
    if cfg.unroll_layers:
        for i in range(cfg.n_enc_layers):
            lp = jax.tree.map(lambda p: p[i], params["enc_blocks"])
            x, _ = jax.checkpoint(body)(x, lp)
    else:
        x, _ = jax.lax.scan(jax.checkpoint(body), x, params["enc_blocks"])
    return L.rms_norm(params["enc_norm"], x, cfg.norm_eps)


def _dec_block(lp, cfg, x, positions, enc_out, enc_positions, want_cache):
    h = L.rms_norm(lp["norm1"], x, cfg.norm_eps)
    cache = None
    if want_cache:
        y, (k, v) = L.mha(lp["self_attn"], _spec(cfg, True), h, positions, return_kv=True)
        cache = {"k": k, "v": v}
    else:
        y = L.mha(lp["self_attn"], _spec(cfg, True), h, positions)
    x = x + y
    hx = L.rms_norm(lp["norm_x"], x, cfg.norm_eps)
    x = x + L.mha(
        lp["cross_attn"], _spec(cfg, False), hx, positions,
        kv_x=enc_out, kv_positions=enc_positions, use_rope=False,
    )
    x = x + L.ffn(lp["ffn"], L.rms_norm(lp["norm2"], x, cfg.norm_eps), cfg.act)
    return x, cache


def decode_train(params, cfg: ModelConfig, tokens, enc_out):
    b, s_dec = tokens.shape
    s_enc = enc_out.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s_dec), (b, s_dec))
    enc_pos = jnp.broadcast_to(jnp.arange(s_enc), (b, s_enc))
    x = L.embed(params["embed"], tokens)

    def body(x, lp):
        x, _ = _dec_block(lp, cfg, x, positions, enc_out, enc_pos, False)
        return x, None

    if cfg.unroll_layers:
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda p: p[i], params["dec_blocks"])
            x, _ = jax.checkpoint(body)(x, lp)
    else:
        x, _ = jax.lax.scan(jax.checkpoint(body), x, params["dec_blocks"])
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    return L.unembed(params["embed"], x)


def encdec_prefill(params, cfg: ModelConfig, tokens, frames):
    """Returns (logits, caches) with caches = {self: stacked kv, cross: stacked kv}."""
    enc_out = encode(params, cfg, frames)
    b, s_dec = tokens.shape
    s_enc = enc_out.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s_dec), (b, s_dec))
    enc_pos = jnp.broadcast_to(jnp.arange(s_enc), (b, s_enc))
    x = L.embed(params["embed"], tokens)

    def body(x, lp):
        x, cache = _dec_block(lp, cfg, x, positions, enc_out, enc_pos, True)
        # also emit cross K/V for this layer
        spec = _spec(cfg, False)
        ck = L.dense(lp["cross_attn"]["wk"], enc_out).reshape(b, s_enc, spec.n_kv_heads, spec.head_dim)
        cv = L.dense(lp["cross_attn"]["wv"], enc_out).reshape(b, s_enc, spec.n_kv_heads, spec.head_dim)
        return x, {"self": cache, "cross": {"k": ck, "v": cv}}

    if cfg.unroll_layers:
        outs = []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda p: p[i], params["dec_blocks"])
            x, c = body(x, lp)
            outs.append(c)
        caches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    else:
        x, caches = jax.lax.scan(body, x, params["dec_blocks"])
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    return L.unembed(params["embed"], x), caches


def encdec_decode(params, cfg: ModelConfig, caches, token, pos):
    """One-token decode. caches: {"self": {k,v} stacked, "cross": {k,v} stacked}."""
    b = token.shape[0]
    x = L.embed(params["embed"], token)
    spec_self = _spec(cfg, True)
    spec_cross = _spec(cfg, False)

    def body(x, inp):
        lp, c = inp
        h = L.rms_norm(lp["norm1"], x, cfg.norm_eps)
        y, ck, cv = L.mha_decode(lp["self_attn"], spec_self, h, c["self"]["k"], c["self"]["v"], pos)
        x = x + y
        hx = L.rms_norm(lp["norm_x"], x, cfg.norm_eps)
        # cross attention against precomputed encoder K/V (no mask, no rope)
        kx, vx = c["cross"]["k"], c["cross"]["v"]
        q = L.dense(lp["cross_attn"]["wq"], hx).reshape(b, 1, spec_cross.n_heads, spec_cross.head_dim)
        rep = spec_cross.n_heads // spec_cross.n_kv_heads
        qg = q.reshape(b, 1, spec_cross.n_kv_heads, rep, spec_cross.head_dim)
        sc = jnp.einsum("bqkrh,bskh->bkrqs", qg, kx).astype(jnp.float32) / (spec_cross.head_dim ** 0.5)
        w = jax.nn.softmax(sc, axis=-1).astype(x.dtype)
        o = jnp.einsum("bkrqs,bskh->bqkrh", w, vx).reshape(b, 1, spec_cross.n_heads * spec_cross.head_dim)
        x = x + L.dense(lp["cross_attn"]["wo"], o)
        x = x + L.ffn(lp["ffn"], L.rms_norm(lp["norm2"], x, cfg.norm_eps), cfg.act)
        return x, {"self": {"k": ck, "v": cv}, "cross": c["cross"]}

    if cfg.unroll_layers:
        outs = []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda p: p[i], params["dec_blocks"])
            cc = jax.tree.map(lambda v: v[i], caches)
            x, nc = body(x, (lp, cc))
            outs.append(nc)
        new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    else:
        x, new_caches = jax.lax.scan(body, x, (params["dec_blocks"], caches))
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    return L.unembed(params["embed"], x), new_caches


def encdec_cache_specs(cfg: ModelConfig, batch: int, s_dec: int, s_enc: int):
    dt = cfg.param_dtype
    hd = cfg.resolved_head_dim
    nl = cfg.n_layers
    kv = lambda s: {
        "k": jax.ShapeDtypeStruct((nl, batch, s, cfg.n_kv_heads, hd), dt),
        "v": jax.ShapeDtypeStruct((nl, batch, s, cfg.n_kv_heads, hd), dt),
    }
    return {"self": kv(s_dec), "cross": kv(s_enc)}
