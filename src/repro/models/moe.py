"""Mixture-of-Experts FFN with shared + routed experts (Qwen-MoE / Llama-4).

Baseline dispatch is the GShard dense-einsum formulation (capacity-based,
token-dropping): fully partitionable under GSPMD with experts on the
`model` mesh axis (EP), dispatch/combine lowering to all-to-alls. A
sort-based dispatch variant exists for the perf pass (see EXPERIMENTS §Perf).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.distributed import shard
from repro.models.layers import _act_fn, dense, init_dense, init_ffn, ffn


@dataclasses.dataclass(frozen=True)
class MoESpec:
    d_model: int
    n_experts: int
    top_k: int
    expert_d_ff: int
    n_shared_experts: int = 0
    shared_d_ff: int = 0          # d_ff of the shared expert block (total)
    capacity_factor: float = 1.25
    act: str = "silu"
    moe_group: int = 1024         # tokens per dispatch group; capacity and
                                  # dispatch-einsum FLOPs scale with it
    dispatch: str = "einsum"      # "einsum" (GShard baseline) | "sort"


def init_moe(key, s: MoESpec, dtype):
    kr, ke, ks = jax.random.split(key, 3)
    E, D, F = s.n_experts, s.d_model, s.expert_d_ff
    scale = 1.0 / math.sqrt(D)
    kg, ku, kd = jax.random.split(ke, 3)
    p = {
        "router": init_dense(kr, D, E, dtype),
        "experts": {
            "gate": (jax.random.normal(kg, (E, D, F), jnp.float32) * scale).astype(dtype),
            "up": (jax.random.normal(ku, (E, D, F), jnp.float32) * scale).astype(dtype),
            "down": (jax.random.normal(kd, (E, F, D), jnp.float32) / math.sqrt(F)).astype(dtype),
        },
    }
    if s.n_shared_experts:
        p["shared"] = init_ffn(ks, D, s.shared_d_ff or s.expert_d_ff * s.n_shared_experts, dtype, s.act)
    return p


def _routing(p, s: MoESpec, x2d: jnp.ndarray):
    """x2d: (T, D) -> top-k expert ids/weights + aux load-balance loss."""
    logits = dense(p["router"], x2d).astype(jnp.float32)            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, s.top_k)                    # (T, k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    # Switch-style aux loss: E * sum_e fraction_tokens_e * mean_prob_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(ids, s.n_experts, dtype=jnp.float32), axis=1), axis=0
    )
    aux = s.n_experts * jnp.sum(me * ce)
    return ids, weights.astype(x2d.dtype), aux


def moe_ffn(p, s: MoESpec, x: jnp.ndarray):
    """x: (B, S, D) -> (y, aux_loss).

    GShard capacity-based einsum dispatch over token *groups* of
    `moe_group`: capacity C = ceil(k * g * cf / E) scales with the group
    size, which keeps the dispatch-einsum FLOPs (T*E*C*D ~ T*g*k*cf*D) a
    small fraction of expert FLOPs. Groups stay data-sharded; experts live
    on the model axis, so dispatch/combine lower to all-to-alls under GSPMD.
    """
    b, sl, d = x.shape
    t = b * sl
    g = min(s.moe_group, t)
    while t % g:
        g //= 2
    ng = t // g
    x2d = x.reshape(t, d)
    ids, weights, aux = _routing(p, s, x2d)
    cap = max(1, int(math.ceil(s.top_k * g * s.capacity_factor / s.n_experts)))

    xg = x2d.reshape(ng, g, d)
    ids_g = ids.reshape(ng, g, s.top_k)
    w_g = weights.reshape(ng, g, s.top_k)

    # position of each (token, choice) within its expert, per group
    onehot = jax.nn.one_hot(ids_g, s.n_experts, dtype=jnp.int32)       # (G,g,k,E)
    pos_in_e = jnp.cumsum(onehot.reshape(ng, g * s.top_k, s.n_experts), axis=1)
    pos_in_e = (pos_in_e - 1).reshape(ng, g, s.top_k, s.n_experts)
    keep = (pos_in_e < cap) & (onehot > 0)                              # (G,g,k,E)
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos_in_e, -1), cap, dtype=xg.dtype)
    kd = keep.astype(xg.dtype)
    disp = jnp.einsum("gske,gskec->gsec", kd, pos_oh)                   # (G,g,E,C)
    comb = jnp.einsum("gsk,gske,gskec->gsec", w_g, kd, pos_oh)

    xe = jnp.einsum("gsec,gsd->gecd", disp, xg)            # (G, E, C, D)
    xe = shard(xe, "batch", "act_experts", None, None)     # all-to-all: g->E
    we = p["experts"]
    gh = jnp.einsum("gecd,edf->gecf", xe, we["gate"])
    uh = jnp.einsum("gecd,edf->gecf", xe, we["up"])
    h = _act_fn(s.act)(gh) * uh
    ye = jnp.einsum("gecf,efd->gecd", h, we["down"])       # (G, E, C, D)
    ye = shard(ye, "batch", "act_experts", None, None)
    y = jnp.einsum("gsec,gecd->gsd", comb, ye)             # (G, g, D)
    y = y.reshape(b, sl, d)
    y = shard(y, "batch", "seq", None)

    if "shared" in p:
        y = y + ffn(p["shared"], x, s.act)
    return y, aux
