"""Public model API: build_model(cfg) -> Model.

Model exposes pure functions used by the train loop, the serving driver and
the dry-run launcher:

    init(rng)                          -> params
    loss_fn(params, batch)             -> (loss, metrics)
    prefill(params, batch)             -> (logits, caches)
    decode_step(params, caches, token, pos) -> (logits, caches)
    input_specs(shape)                 -> dict of ShapeDtypeStruct
    cache_specs(shape)                 -> ShapeDtypeStruct pytree
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import encdec as ED
from repro.models import layers as L
from repro.models import transformer as T


def lm_loss(
    logits: jnp.ndarray, labels: jnp.ndarray, vocab: int = 0
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Mean masked CE in fp32. labels < 0 are ignored. When the logits dim
    is padded past `vocab` (sharding-friendly padded_vocab), padded ids are
    masked to -inf so they carry no probability mass."""
    lf = logits.astype(jnp.float32)
    if vocab and lf.shape[-1] > vocab:
        pad_mask = jnp.arange(lf.shape[-1]) >= vocab
        lf = jnp.where(pad_mask[None, None, :], -1e30, lf)
    logz = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = (labels >= 0).astype(jnp.float32)
    tot = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(nll * mask) / tot, tot


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable
    loss_fn: Callable
    prefill: Callable
    decode_step: Callable
    input_specs: Callable
    cache_specs: Callable

    def param_specs(self, seed: int = 0):
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(seed)))


# --------------------------------------------------------------------------
def _frontend_tokens(cfg: ModelConfig, shape: ShapeSpec) -> int:
    """#positions supplied by the modality frontend stub."""
    if cfg.frontend == "vision_stub":
        return min(cfg.n_frontend_tokens, shape.seq_len // 2)
    return 0


def build_model(cfg: ModelConfig) -> Model:
    cfg.validate()
    if cfg.enc_dec:
        return _build_encdec(cfg)
    return _build_decoder(cfg)


# ------------------------------------------------------------ decoder LMs --
def _build_decoder(cfg: ModelConfig) -> Model:
    aux_coeff = 0.01 if cfg.n_experts else 0.0

    def init(rng):
        params = T.init_decoder(rng, cfg)
        if cfg.weight_quant:
            params = L.quantize_dense_weights(params)
        return params

    def _embed_inputs(params, batch):
        """Token (+ frontend) embeddings and positions."""
        tokens = batch["tokens"]
        x = L.embed(params["embed"], tokens)
        if cfg.frontend == "vision_stub" and "patch_embeds" in batch:
            patches = batch["patch_embeds"].astype(x.dtype)
            x = jnp.concatenate([patches, x], axis=1)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        return x, positions

    def loss_fn(params, batch):
        x, positions = _embed_inputs(params, batch)
        hidden, aux = T.decoder_hidden(params, cfg, x, positions)
        n_front = x.shape[1] - batch["tokens"].shape[1]
        if n_front:
            hidden = hidden[:, n_front:]
        logits = T.logits_from_hidden(params, cfg, hidden)
        loss, n_tok = lm_loss(logits, batch["labels"], cfg.vocab)
        total = loss + aux_coeff * aux
        return total, {"loss": loss, "aux_loss": aux, "tokens": n_tok}

    def prefill(params, batch, cache_len: int = 0):
        x, positions = _embed_inputs(params, batch)
        hidden, caches = T.decoder_prefill(params, cfg, x, positions, smax=cache_len)
        logits = T.logits_from_hidden(params, cfg, hidden[:, -1:])
        return logits, caches

    def decode_step(params, caches, token, pos):
        x = L.embed(params["embed"], token)
        hidden, caches = T.decoder_decode(params, cfg, caches, x, pos)
        logits = T.logits_from_hidden(params, cfg, hidden)
        return logits, caches

    def input_specs(shape: ShapeSpec) -> dict[str, Any]:
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        n_front = _frontend_tokens(cfg, shape)
        if shape.kind == "train":
            specs = {
                "tokens": jax.ShapeDtypeStruct((b, s - n_front), i32),
                "labels": jax.ShapeDtypeStruct((b, s - n_front), i32),
            }
        elif shape.kind == "prefill":
            specs = {"tokens": jax.ShapeDtypeStruct((b, s - n_front), i32)}
        else:  # decode
            return {
                "token": jax.ShapeDtypeStruct((b, 1), i32),
                "pos": jax.ShapeDtypeStruct((), i32),
            }
        if n_front:
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, n_front, cfg.d_model), cfg.param_dtype
            )
        return specs

    def cache_specs(shape: ShapeSpec):
        return T.decoder_cache_specs(cfg, shape.global_batch, shape.seq_len)

    return Model(cfg, init, loss_fn, prefill, decode_step, input_specs, cache_specs)


# ----------------------------------------------------------- enc-dec (ASR) --
def _build_encdec(cfg: ModelConfig) -> Model:
    def init(rng):
        return ED.init_encdec(rng, cfg)

    def _split(shape: ShapeSpec) -> tuple[int, int]:
        """seq_len budget split: half encoder frames, half decoder tokens."""
        return shape.seq_len // 2, shape.seq_len // 2

    def loss_fn(params, batch):
        enc_out = ED.encode(params, cfg, batch["frame_embeds"])
        logits = ED.decode_train(params, cfg, batch["tokens"], enc_out)
        loss, n_tok = lm_loss(logits, batch["labels"], cfg.vocab)
        return loss, {"loss": loss, "aux_loss": jnp.zeros((), jnp.float32), "tokens": n_tok}

    def prefill(params, batch):
        logits, caches = ED.encdec_prefill(params, cfg, batch["tokens"], batch["frame_embeds"])
        return logits[:, -1:], caches

    def decode_step(params, caches, token, pos):
        return ED.encdec_decode(params, cfg, caches, token, pos)

    def input_specs(shape: ShapeSpec) -> dict[str, Any]:
        b = shape.global_batch
        s_enc, s_dec = _split(shape)
        i32 = jnp.int32
        if shape.kind == "train":
            return {
                "frame_embeds": jax.ShapeDtypeStruct((b, s_enc, cfg.d_model), cfg.param_dtype),
                "tokens": jax.ShapeDtypeStruct((b, s_dec), i32),
                "labels": jax.ShapeDtypeStruct((b, s_dec), i32),
            }
        if shape.kind == "prefill":
            return {
                "frame_embeds": jax.ShapeDtypeStruct((b, s_enc, cfg.d_model), cfg.param_dtype),
                "tokens": jax.ShapeDtypeStruct((b, s_dec), i32),
            }
        return {
            "token": jax.ShapeDtypeStruct((b, 1), i32),
            "pos": jax.ShapeDtypeStruct((), i32),
        }

    def cache_specs(shape: ShapeSpec):
        s_enc, s_dec = _split(shape)
        return ED.encdec_cache_specs(cfg, shape.global_batch, s_dec, s_enc)

    return Model(cfg, init, loss_fn, prefill, decode_step, input_specs, cache_specs)
