"""Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 style).

Prefill: query low-rank path (q_lora) and compressed KV latent c_kv
(kv_lora_rank) + a shared rope key (qk_rope_head_dim); keys/values expanded
per head for standard attention.

Decode: *absorbed* form — the per-head expansion matrices W_uk / W_uv are
absorbed into the query / output projections so attention runs directly over
the (S, r + rope) latent cache. This is MLA's deployment win (tiny cache,
no per-step expansion) and the form we lower for decode shapes.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.distributed import shard
from repro.models.layers import apply_rope, dense, init_dense, init_rms_norm, rms_norm


@dataclasses.dataclass(frozen=True)
class MLASpec:
    d_model: int
    n_heads: int
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    impl: str = "dense"
    q_chunk: int = 2048
    kv_chunk: int = 1024
    unroll_inner: bool = False

    @property
    def qk_head_dim(self) -> int:
        return self.qk_nope_head_dim + self.qk_rope_head_dim


def init_mla(key, s: MLASpec, dtype):
    ks = jax.random.split(key, 7)
    H, r = s.n_heads, s.kv_lora_rank
    return {
        "w_dq": init_dense(ks[0], s.d_model, s.q_lora_rank, dtype),
        "q_norm": init_rms_norm(s.q_lora_rank, dtype),
        "w_uq": init_dense(ks[1], s.q_lora_rank, H * s.qk_head_dim, dtype),
        "w_dkv": init_dense(ks[2], s.d_model, r, dtype),
        "kv_norm": init_rms_norm(r, dtype),
        "w_kr": init_dense(ks[3], s.d_model, s.qk_rope_head_dim, dtype),
        "w_uk": init_dense(ks[4], r, H * s.qk_nope_head_dim, dtype),
        "w_uv": init_dense(ks[5], r, H * s.v_head_dim, dtype),
        "wo": init_dense(ks[6], H * s.v_head_dim, s.d_model, dtype),
    }


def _latents(p, s: MLASpec, x, positions):
    """Compressed KV latent + rope key for a full sequence."""
    b, sl, _ = x.shape
    c_kv = rms_norm(p["kv_norm"], dense(p["w_dkv"], x), s.norm_eps)   # (B,S,r)
    k_rope = dense(p["w_kr"], x).reshape(b, sl, 1, s.qk_rope_head_dim)
    k_rope = apply_rope(k_rope, positions, s.rope_theta)
    return c_kv, k_rope


def _queries(p, s: MLASpec, x, positions):
    b, sl, _ = x.shape
    ql = rms_norm(p["q_norm"], dense(p["w_dq"], x), s.norm_eps)
    q = dense(p["w_uq"], ql).reshape(b, sl, s.n_heads, s.qk_head_dim)
    q_nope = q[..., : s.qk_nope_head_dim]
    q_rope = apply_rope(q[..., s.qk_nope_head_dim :], positions, s.rope_theta)
    return q_nope, q_rope


def mla_prefill(p, s: MLASpec, x, positions, mask, return_cache: bool = False):
    """x: (B,S,D); mask: (S,S) additive fp32. Standard (expanded) attention."""
    b, sl, _ = x.shape
    H = s.n_heads
    c_kv, k_rope = _latents(p, s, x, positions)
    q_nope, q_rope = _queries(p, s, x, positions)
    k_nope = dense(p["w_uk"], c_kv).reshape(b, sl, H, s.qk_nope_head_dim)
    v = dense(p["w_uv"], c_kv).reshape(b, sl, H, s.v_head_dim)
    q_nope = shard(q_nope, "batch", "seq", "act_heads", None)
    k_nope = shard(k_nope, "batch", "kv_seq", "act_heads", None)
    v = shard(v, "batch", "kv_seq", "act_heads", None)

    scale = 1.0 / math.sqrt(s.qk_head_dim)
    if s.impl == "chunked":
        from repro.models.layers import chunked_attention

        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)       # (B,S,H,qk)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (b, sl, H, s.qk_rope_head_dim))],
            axis=-1,
        )
        o = chunked_attention(
            q_full[:, :, :, None, :], k_full, v,
            causal=True, window=0, mask_offset=0,
            q_chunk=s.q_chunk, kv_chunk=s.kv_chunk, scale=scale,
            unroll_inner=s.unroll_inner,
        ).astype(x.dtype).reshape(b, sl, H * s.v_head_dim)
    else:
        scores = (
            jnp.einsum("bqhd,bshd->bhqs", q_nope, k_nope)
            + jnp.einsum("bqhd,bsxd->bhqs", q_rope, k_rope)
        ).astype(jnp.float32) * scale
        scores = scores + mask
        w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        o = jnp.einsum("bhqs,bshd->bqhd", w, v).reshape(b, sl, H * s.v_head_dim)
    y = dense(p["wo"], o, in_logical="w_in2", out_logical="w_out2")
    y = shard(y, "batch", "residual_seq", None)
    if return_cache:
        return y, (c_kv, k_rope.reshape(b, sl, s.qk_rope_head_dim))
    return y


def mla_decode(p, s: MLASpec, x, cache_ckv, cache_kr, pos):
    """Absorbed decode. cache_ckv: (B,S,r); cache_kr: (B,S,rope). Returns
    (y, new_ckv, new_kr)."""
    b, one, _ = x.shape
    H, r = s.n_heads, s.kv_lora_rank
    pvec = jnp.full((b, one), pos, jnp.int32)
    c_kv, k_rope = _latents(p, s, x, pvec)
    cache_ckv = jax.lax.dynamic_update_slice_in_dim(
        cache_ckv, c_kv.astype(cache_ckv.dtype), pos, axis=1
    )
    cache_kr = jax.lax.dynamic_update_slice_in_dim(
        cache_kr, k_rope.reshape(b, one, s.qk_rope_head_dim).astype(cache_kr.dtype), pos, axis=1
    )
    cache_ckv = shard(cache_ckv, "batch", "kv_seq", None)

    q_nope, q_rope = _queries(p, s, x, pvec)
    # Absorb W_uk into q: q_lat (B,1,H,r) = q_nope @ W_uk^T (per head).
    from repro.models.layers import raw_weight

    w_uk = raw_weight(p["w_uk"], x.dtype).reshape(r, H, s.qk_nope_head_dim)
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_uk)
    scale = 1.0 / math.sqrt(s.qk_head_dim)
    scores = (
        jnp.einsum("bqhr,bsr->bhqs", q_lat, cache_ckv)
        + jnp.einsum("bqhd,bsd->bhqs", q_rope, cache_kr)
    ).astype(jnp.float32) * scale
    smax = cache_ckv.shape[1]
    ok = jnp.arange(smax)[None, None, None, :] <= pos
    scores = jnp.where(ok, scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bhqs,bsr->bqhr", w, cache_ckv)       # (B,1,H,r)
    # Absorb W_uv into the output projection.
    w_uv = raw_weight(p["w_uv"], x.dtype).reshape(r, H, s.v_head_dim)
    o = jnp.einsum("bqhr,rhd->bqhd", o_lat, w_uv).reshape(b, one, H * s.v_head_dim)
    y = dense(p["wo"], o, in_logical="w_in2", out_logical="w_out2")
    return y, cache_ckv, cache_kr
