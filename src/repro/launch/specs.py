"""Dry-run sharding assembly: rules per (shape-kind, mesh), input/cache
shardings, and the roofline bookkeeping helpers."""

from __future__ import annotations

import re
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.distributed.sharding import ShardingRules, make_rules, param_specs_for_tree
from repro.launch.mesh import data_axes, mesh_axis_sizes

# ------------------------------------------------------------------- rules --
def rules_for(mesh, shape: ShapeSpec, overrides: dict | None = None) -> ShardingRules:
    dp = data_axes(mesh)
    kw: dict[str, Any] = dict(data_axes=dp, model_axis="model", fsdp_axis="data")
    if shape.kind == "decode":
        # Serving: FSDP weight-sharding would re-gather weights every step;
        # keep weights TP-only (model axis), replicated across data.
        kw["fsdp_axis"] = None
        if shape.global_batch == 1:
            # long-context decode: nothing to DP over; spread the KV/state
            # sequence across the whole mesh.
            kw["data_axes"] = None
            kw["kv_seq_axis"] = tuple([*dp, "model"])
        else:
            kw["kv_seq_axis"] = "model"   # flash-decoding style seq split
    rules = make_rules(**kw)
    if shape.kind == "decode":
        # the model axis is spent on the KV sequence; heads stay replicated
        rules = rules.with_overrides(act_heads=None)
    if overrides:
        rules = rules.with_overrides(**overrides)
    return ShardingRules(rules.rules, mesh_axis_sizes(mesh))


# --------------------------------------------------------------- shardings --
def _named(mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def param_shardings(mesh, rules: ShardingRules, tree):
    specs = param_specs_for_tree(tree, rules)
    return jax.tree.map(lambda s: _named(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_shardings(mesh, rules: ShardingRules, specs: dict):
    """Input batch: leading dim = global batch -> DP axes."""
    out = {}
    for k, s in specs.items():
        if s.shape == ():
            out[k] = _named(mesh, P())
            continue
        dims: list[Any] = [rules.axis("batch")] + [None] * (len(s.shape) - 1)
        out[k] = _named(mesh, rules.guard_spec(P(*dims), s.shape))
    return out


def cache_shardings(mesh, rules: ShardingRules, cache_tree):
    """Decode caches. Leaf-name based placement:
    k/v/ckv/kr: (..., B, S, [K], hd) -> (batch, kv_seq); mamba state
    (..., B, H, N, P) -> heads on model; conv/state widths on model."""

    def spec_for(path, leaf) -> P:
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        name = keys[-1]
        nd = len(leaf.shape)
        batch_ax = rules.axis("batch")
        seq_ax = rules.axis("kv_seq")
        if name == "pos_idx":
            return P(*([None] * nd))
        if name in ("k", "v"):          # (G?, B, S, K, hd)
            dims = [None] * nd
            dims[-4], dims[-3] = batch_ax, seq_ax
            return rules.guard_spec(P(*dims), leaf.shape)
        if name in ("ckv", "kr"):        # (G?, B, S, r)
            dims = [None] * nd
            dims[-3], dims[-2] = batch_ax, seq_ax
            return rules.guard_spec(P(*dims), leaf.shape)
        if name == "state":
            dims = [None] * nd
            if nd >= 4:   # mamba: (G?, B, H, N, P) — batch, then heads on model
                dims[-4], dims[-3] = batch_ax, "model"
            else:         # rglru: (G?, B, W) — batch, width on model
                dims[-2], dims[-1] = batch_ax, "model"
            return rules.guard_spec(P(*dims), leaf.shape)
        if name == "conv":               # (G?, B, K-1, C)
            dims = [None] * nd
            dims[-3], dims[-1] = batch_ax, "model"
            return rules.guard_spec(P(*dims), leaf.shape)
        return P(*([None] * nd))

    specs = jax.tree_util.tree_map_with_path(spec_for, cache_tree)
    return jax.tree.map(lambda s: _named(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------- roofline --
PEAK_FLOPS = 197e12          # bf16 / chip (TPU v5e)
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9_]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)\b"
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-device bytes moved by each collective kind (post-SPMD HLO)."""
    out: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_txt, op = m.group(1), m.group(2)
        out[op] = out.get(op, 0) + _shape_bytes(shape_txt)
    return out


def model_flops(cfg: ModelConfig, shape: ShapeSpec, n_params: int, n_active: int) -> float:
    """MODEL_FLOPS: 6ND train / 2ND per generated token (decode)."""
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token per seq


def count_params(tree) -> int:
    return sum(int(jnp.size(x)) if hasattr(x, "size") else 0 for x in jax.tree.leaves(tree))


def active_params(cfg: ModelConfig, n_params: int) -> int:
    """MoE: only top_k of n_experts routed experts are active per token."""
    if not cfg.n_experts:
        return n_params
    per_expert = 3 * cfg.d_model * cfg.expert_d_ff
    routed_total = cfg.n_layers * cfg.n_experts * per_expert
    routed_active = cfg.n_layers * cfg.top_k * per_expert
    return n_params - routed_total + routed_active
