"""Training entrypoint.

Single-host (CPU/demo) mode runs real steps on a reduced config with dedup
checkpointing against the in-process shared-nothing cluster; production mode
(--dryrun) lowers the full config under the 256/512-chip mesh (see
dryrun.py, which this wraps for convenience).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-32b --steps 50 \
      --ckpt-every 10 [--resume]
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-110b --dryrun
"""

from __future__ import annotations

import argparse

import jax


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", default=None, help="checkpoint name to resume from")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--nodes", type=int, default=4, help="dedup storage nodes")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--dryrun", action="store_true")
    ap.add_argument("--shape", default="train_4k")
    args = ap.parse_args()

    if args.dryrun:
        # Re-exec through dryrun so XLA_FLAGS lands before jax init.
        import os
        import subprocess
        import sys

        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", args.arch, "--shape", args.shape, "--force"]
        raise SystemExit(subprocess.call(cmd, env=os.environ))

    from repro.checkpoint import DedupCheckpointer
    from repro.configs import get_config
    from repro.core import ChunkingSpec, DedupCluster
    from repro.data import SyntheticLMData
    from repro.models import build_model
    from repro.optim import AdamWConfig
    from repro.train import TrainConfig, train_loop
    from repro.train.loop import init_train_state

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    data = SyntheticLMData(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch)
    cluster = DedupCluster.create(args.nodes, replicas=2,
                                  chunking=ChunkingSpec("fixed", 256 * 1024))
    ck = DedupCheckpointer(cluster)
    opt = AdamWConfig(total_steps=args.steps, compress_grads=args.compress_grads)
    tcfg = TrainConfig(steps=args.steps, accum=args.accum,
                       checkpoint_every=args.ckpt_every, opt=opt)

    state = None
    start = 0
    if args.resume:
        template = init_train_state(model, jax.random.PRNGKey(0), opt)
        state = ck.restore(args.resume, like=template)
        start = int(args.resume.split("-")[-1])
        print(f"resumed from {args.resume} at step {start}")

    state, hist = train_loop(model, data, tcfg, checkpointer=ck, state=state, start_step=start)
    for h in hist:
        print(f"step {h['step']:5d} loss {h['loss']:.4f} ({h['sec']:.2f}s)")
    if args.ckpt_every:
        print("checkpoints:", ck.list_checkpoints())
        print("dedup space savings: %.1f%%" % (100 * cluster.space_savings()))
        print("ckpt stats:", ck.stats)


if __name__ == "__main__":
    main()
