"""Production mesh construction.

Single pod: (16, 16) over ("data", "model") — 256 chips (TPU v5e pod).
Multi-pod:  (2, 16, 16) over ("pod", "data", "model") — 512 chips; "pod" is
pure data parallelism over the DCN/optical inter-pod links.

A FUNCTION, not a module constant: importing this module must never touch
jax device state (smoke tests see 1 CPU device, the dry-run sees 512 host
devices via XLA_FLAGS set before any jax import).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_axis_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def data_axes(mesh) -> tuple[str, ...]:
    """All pure-DP axes of the mesh ("pod" + "data" when present)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
