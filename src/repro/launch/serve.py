"""Serving entrypoint: batched requests against a decoder LM with
cluster-wide KV prefix-cache dedup.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-32b --requests 16
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-110b --dryrun --shape decode_32k
"""

from __future__ import annotations

import argparse

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--gen-tokens", type=int, default=8)
    ap.add_argument("--shared-prefix", type=int, default=48)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--dryrun", action="store_true")
    ap.add_argument("--shape", default="decode_32k")
    args = ap.parse_args()

    if args.dryrun:
        import os
        import subprocess
        import sys

        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", args.arch, "--shape", args.shape, "--force"]
        raise SystemExit(subprocess.call(cmd, env=os.environ))

    import dataclasses

    import jax

    from repro.configs import get_config
    from repro.core import ChunkingSpec, DedupCluster
    from repro.models import build_model
    from repro.serving import BatchedServer, ServeConfig

    cfg = get_config(args.arch).reduced()
    if set(cfg.block_pattern) != {"attn_global"}:
        cfg = dataclasses.replace(cfg, block_pattern=("attn_global",), window=0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cluster = DedupCluster.create(args.nodes, chunking=ChunkingSpec("fixed", 64 * 1024))
    srv = BatchedServer(model, params, cluster,
                        ServeConfig(max_len=args.shared_prefix + 64, block_tokens=8))

    rng = np.random.default_rng(0)
    shared = [int(t) for t in rng.integers(0, cfg.vocab, args.shared_prefix)]
    for i in range(args.requests):
        suffix = [int(t) for t in rng.integers(0, cfg.vocab, 8)]
        r = srv.handle(shared + suffix, gen_tokens=args.gen_tokens)
        print(f"req {i:3d}: reused={r['reused_tokens']:4d} computed={r['computed_tokens']:4d}")
    s = srv.kv.stats
    print(f"prefix-cache hit rate: {s.hit_rate:.2%}  tokens reused: {s.tokens_reused}")
    print(f"cluster space savings: {100 * cluster.space_savings():.1f}%")


if __name__ == "__main__":
    main()
