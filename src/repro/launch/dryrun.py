import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_EXTRA", "") + " --xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell we:
  1. build the FULL model config, eval_shape the step function inputs
     (ShapeDtypeStruct only — no allocation),
  2. jit with explicit in_shardings from the rules tables,
  3. .lower().compile() under the production mesh,
  4. record memory_analysis / cost_analysis / per-collective bytes into
     results/dryrun/<arch>__<shape>__<mesh>.json for §Dry-run + §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-32b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""

import argparse
import json
import pathlib
import time
import traceback

import jax

from repro.configs import ARCHS, SHAPES, get_config, shape_applicable
from repro.configs.base import ModelConfig, ShapeSpec
from repro.distributed.sharding import use_sharding_rules
from repro.launch import specs as SP
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.train.loop import build_train_step, init_train_state

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _mesh_tag(multi_pod: bool) -> str:
    return "pod2x16x16" if multi_pod else "pod16x16"


def _lower_compile(cfg: ModelConfig, shape: ShapeSpec, mesh, rules, opt_cfg):
    """Lower + compile one step function; returns (compiled, n_params)."""
    model = build_model(cfg)
    with jax.set_mesh(mesh), use_sharding_rules(rules):
        if shape.kind == "train":
            state_specs = jax.eval_shape(
                lambda: init_train_state(model, jax.random.PRNGKey(0), opt_cfg)
            )
            in_specs = model.input_specs(shape)
            fn = build_train_step(model, opt_cfg)
            in_sh = (
                SP.param_shardings(mesh, rules, state_specs),
                SP.batch_shardings(mesh, rules, in_specs),
            )
            lowered = jax.jit(fn, in_shardings=in_sh).lower(state_specs, in_specs)
            n_params = SP.count_params(state_specs["params"])
        elif shape.kind == "prefill":
            p_specs = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
            in_specs = model.input_specs(shape)
            fn = lambda params, batch: model.prefill(params, batch)
            in_sh = (
                SP.param_shardings(mesh, rules, p_specs),
                SP.batch_shardings(mesh, rules, in_specs),
            )
            lowered = jax.jit(fn, in_shardings=in_sh).lower(p_specs, in_specs)
            n_params = SP.count_params(p_specs)
        else:  # decode
            p_specs = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
            cache_specs = model.cache_specs(shape)
            in_specs = model.input_specs(shape)
            fn = model.decode_step
            in_sh = (
                SP.param_shardings(mesh, rules, p_specs),
                SP.cache_shardings(mesh, rules, cache_specs),
                SP.batch_shardings(mesh, rules, in_specs)["token"],
                SP.batch_shardings(mesh, rules, in_specs)["pos"],
            )
            lowered = jax.jit(fn, in_shardings=in_sh).lower(
                p_specs, cache_specs, in_specs["token"], in_specs["pos"]
            )
            n_params = SP.count_params(p_specs)
        compiled = lowered.compile()
    return compiled, n_params


def _cost_variant(cfg: ModelConfig, k: int) -> ModelConfig:
    """Unrolled k-group config for per-group cost extraction (XLA counts
    while-loop bodies once, so the scanned program undercounts FLOPs and
    collective bytes; we extrapolate from unrolled 1- and 2-group builds)."""
    import dataclasses

    if cfg.enc_dec:
        return dataclasses.replace(cfg, n_layers=k, n_enc_layers=k, unroll_layers=True)
    tail = len(cfg.tail_blocks)
    return dataclasses.replace(
        cfg, n_layers=k * cfg.pattern_len + tail, unroll_layers=True
    )


def _costs(compiled) -> dict:
    cost = compiled.cost_analysis() or {}
    coll = SP.collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": {k: float(v) for k, v in coll.items()},
    }


def _extrapolate(c1: dict, c2: dict, g: int) -> dict:
    """total = cost(1 group) + (cost(2) - cost(1)) * (G - 1)."""
    out = {
        "flops": c1["flops"] + (c2["flops"] - c1["flops"]) * (g - 1),
        "bytes": c1["bytes"] + (c2["bytes"] - c1["bytes"]) * (g - 1),
    }
    kinds = set(c1["coll"]) | set(c2["coll"])
    out["coll"] = {
        k: c1["coll"].get(k, 0.0) + (c2["coll"].get(k, 0.0) - c1["coll"].get(k, 0.0)) * (g - 1)
        for k in kinds
    }
    return out


def dryrun_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    overrides: dict | None = None,
    tag: str = "",
    verbose: bool = True,
    cfg_override: ModelConfig | None = None,
) -> dict:
    """Lower+compile one cell (full scanned program for memory/compile
    proof + two unrolled variants for roofline costing)."""
    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": _mesh_tag(multi_pod),
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = SP.rules_for(mesh, shape, overrides)
    opt_cfg = AdamWConfig()
    t0 = time.time()

    compiled, n_params = _lower_compile(cfg, shape, mesh, rules, opt_cfg)
    g = cfg.n_layers if cfg.enc_dec else cfg.n_groups
    c1 = _costs(_lower_compile(_cost_variant(cfg, 1), shape, mesh, rules, opt_cfg)[0])
    c2 = _costs(_lower_compile(_cost_variant(cfg, 2), shape, mesh, rules, opt_cfg)[0])
    tot = _extrapolate(c1, c2, g)

    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    coll = tot["coll"]
    n_chips = mesh.devices.size
    n_active = SP.active_params(cfg, n_params)

    flops_dev = tot["flops"]
    bytes_dev = tot["bytes"]
    coll_dev = float(sum(coll.values()))
    t_compute = flops_dev / SP.PEAK_FLOPS
    t_memory = bytes_dev / SP.HBM_BW
    t_coll = coll_dev / SP.ICI_BW
    mflops = SP.model_flops(cfg, shape, n_params, n_active)

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": _mesh_tag(multi_pod),
        "tag": tag,
        "status": "ok",
        "compile_s": round(compile_s, 1),
        "n_chips": n_chips,
        "n_params": n_params,
        "n_active_params": n_active,
        "per_device": {
            "hlo_flops": flops_dev,
            "hlo_bytes": bytes_dev,
            "collective_bytes": coll_dev,
            "collectives": coll,
        },
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "temp_size_in_bytes", 0) or 0)
            + (getattr(mem, "argument_size_in_bytes", 0) or 0),
        },
        "roofline": {
            "t_compute_s": t_compute,
            "t_memory_s": t_memory,
            "t_collective_s": t_coll,
            "bottleneck": max(
                [("compute", t_compute), ("memory", t_memory), ("collective", t_coll)],
                key=lambda kv: kv[1],
            )[0],
            "model_flops_total": mflops,
            "useful_flops_ratio": (mflops / (flops_dev * n_chips)) if flops_dev else 0.0,
            "roofline_fraction": (
                (mflops / SP.PEAK_FLOPS / n_chips)
                / max(t_compute, t_memory, t_coll)
                if max(t_compute, t_memory, t_coll) > 0
                else 0.0
            ),
        },
    }
    if verbose:
        r = rec["roofline"]
        print(
            f"[{rec['mesh']}] {arch:26s} {shape_name:12s} ok "
            f"compile={compile_s:6.1f}s compute={r['t_compute_s']*1e3:8.2f}ms "
            f"mem={r['t_memory_s']*1e3:8.2f}ms coll={r['t_collective_s']*1e3:8.2f}ms "
            f"bound={r['bottleneck']:10s} useful={r['useful_flops_ratio']:.2f} "
            f"roofline={r['roofline_fraction']:.3f}",
            flush=True,
        )
    return rec


def save_record(rec: dict) -> pathlib.Path:
    RESULTS.mkdir(parents=True, exist_ok=True)
    tag = f"__{rec['tag']}" if rec.get("tag") else ""
    f = RESULTS / f"{rec['arch']}__{rec['shape']}__{rec.get('mesh','-')}{tag}.json"
    f.write_text(json.dumps(rec, indent=2))
    return f


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    cells: list[tuple[str, str]] = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    n_ok = n_skip = n_fail = 0
    for arch, shape in cells:
        out = RESULTS / f"{arch}__{shape}__{_mesh_tag(args.multi_pod)}.json"
        if out.exists() and not args.force:
            rec = json.loads(out.read_text())
            print(f"[cached] {arch} {shape} -> {rec['status']}", flush=True)
            n_ok += rec["status"] == "ok"
            n_skip += rec["status"] == "skipped"
            continue
        try:
            rec = dryrun_cell(arch, shape, multi_pod=args.multi_pod)
        except Exception as e:
            traceback.print_exc()
            rec = {
                "arch": arch, "shape": shape, "mesh": _mesh_tag(args.multi_pod),
                "status": "fail", "error": f"{type(e).__name__}: {e}"[:2000],
            }
        save_record(rec)
        n_ok += rec["status"] == "ok"
        n_skip += rec["status"] == "skipped"
        n_fail += rec["status"] == "fail"
    print(f"dry-run done: ok={n_ok} skipped={n_skip} failed={n_fail}")


if __name__ == "__main__":
    main()
