"""Deduplicated, fault-tolerant distributed checkpointing.

This is the paper's technique integrated as a first-class framework feature:

* every pytree leaf is serialized, chunked, SHA-256-fingerprinted and placed
  *cluster-wide by content fingerprint* on the shared-nothing DedupCluster;
* repeated checkpoints dedup against each other (optimizer ints, frozen
  embeddings, converged tensors, replicated experts, multi-run storage);
* commit flags + GC make a crash mid-save harmless (no journal);
* restore hits the read path's consistency check, which repairs
  missing/invalid chunks from replicas — the paper §2.4 duplicate-write case.

Device-fingerprint fast path (beyond paper, uses the Pallas kernel): before
pulling a tensor to the host, fingerprint it on device and compare with the
previous save; unchanged tensors are written by *reference* (refcount-only
unicasts, no data motion). Falls back to a full write if any referenced
chunk is missing (repair), so the fast path is safe.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any

import jax
import numpy as np

from repro.core import DedupCluster, ReadError
from repro.core.chunking import ChunkSpec
from repro.kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class CheckpointConfig:
    prefix: str = "ckpt"
    device_fp_fastpath: bool = True
    # Consolidated chunking surface for the device-fingerprint fast path:
    # kind "cdc" + device=True runs the fused chunk+fingerprint pipeline
    # (ONE CDC launch + ONE fingerprint launch per save wave); kind "fixed"
    # runs fixed-size chunking via fingerprint_tensor_chunks_many (still
    # one fingerprint launch). When unset, built from the legacy fields
    # below (accepted and mapped for one release).
    chunk_spec: ChunkSpec | None = None
    # Legacy chunking spelling (.. deprecated:: prefer ``chunk_spec``):
    fp_chunk_bytes: int = 512 * 1024
    device_cdc: bool = True
    cdc_min_bytes: int = 0      # 0 -> fp_chunk_bytes // 2
    cdc_max_bytes: int = 0      # 0 -> fp_chunk_bytes * 2
    # Streaming ingest: bound the transport wave (and peak host dirty-chunk
    # bytes) for the batched leaf write — the whole checkpoint no longer
    # materializes at once; wave k is on the wire while wave k+1 chunks.
    # 0 = one wave for the whole checkpoint (the legacy shape).
    wave_bytes: int = 0
    # Fingerprint presence-cache capacity for the writing session (0 = off):
    # repeat saves elide CIT probes for chunks the session has positive
    # evidence for (see docs/write_cache.md).
    presence_cache: int = 0

    def resolved_chunk_spec(self) -> ChunkSpec:
        if self.chunk_spec is not None:
            return self.chunk_spec
        return ChunkSpec.for_checkpoint(
            self.fp_chunk_bytes,
            min_bytes=self.cdc_min_bytes,
            max_bytes=self.cdc_max_bytes,
            device=self.device_cdc,
        )


def _leaf_paths(tree: Any) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        out.append((key, leaf))
    return out


def _serialize_leaf(leaf) -> bytes:
    arr = np.asarray(jax.device_get(leaf))
    if arr.dtype.name == "bfloat16":
        arr = arr.view(np.uint16)
        dtype_name = "bfloat16"
    else:
        dtype_name = arr.dtype.name
    header = json.dumps({"dtype": dtype_name, "shape": list(arr.shape)}).encode()
    return len(header).to_bytes(4, "big") + header + arr.tobytes()


def _deserialize_leaf(data: bytes):
    import jax.numpy as jnp

    hlen = int.from_bytes(data[:4], "big")
    meta = json.loads(data[4 : 4 + hlen].decode())
    raw = data[4 + hlen :]
    if meta["dtype"] == "bfloat16":
        arr = np.frombuffer(raw, np.uint16).reshape(meta["shape"])
        return jnp.asarray(arr).view(jnp.bfloat16)
    arr = np.frombuffer(raw, np.dtype(meta["dtype"])).reshape(meta["shape"])
    return jnp.asarray(arr)


class DedupCheckpointer:
    def __init__(self, cluster: DedupCluster, cfg: CheckpointConfig | None = None):
        self.cluster = cluster
        self.cfg = cfg or CheckpointConfig()
        self.spec = self.cfg.resolved_chunk_spec()
        # The writing session: a dedicated DedupClient when streaming waves
        # or a presence cache are configured, else the cluster's default
        # (cache-disabled) session — byte-for-byte the legacy write path.
        if self.cfg.wave_bytes or self.cfg.presence_cache:
            self.session = cluster.client(
                presence_cache=self.cfg.presence_cache,
                wave_bytes=self.cfg.wave_bytes,
            )
        else:
            self.session = None
        # leafpath -> (device fp bytes, object name last written)
        self._last_device_fps: dict[str, tuple[bytes, str]] = {}
        self.stats = {
            "leaves_written": 0,
            "leaves_ref_only": 0,
            "bytes_sent": 0,
            # kernel-launch accounting for the device fast path: asserts the
            # one-CDC-launch + one-fingerprint-launch-per-wave contract
            "cdc_launches": 0,
            "fp_launches": 0,
        }

    # ------------------------------------------------------------------ save
    def save(self, name: str, tree: Any) -> dict[str, Any]:
        leaves = _leaf_paths(tree)
        # Batched device fingerprinting: one kernel launch for ALL array
        # leaves (vs one per leaf), then per-leaf ref-write decisions.
        fp_cache = self._batch_device_fps(leaves)
        manifest = {"name": name, "leaves": []}
        full_writes: list[tuple[str, bytes]] = []
        for key, leaf in leaves:
            obj_name = f"{self.cfg.prefix}/{name}/{key}"
            if self._ref_write(key, leaf, obj_name, fp_cache.get(key)):
                manifest["leaves"].append({"key": key, "object": obj_name, "ref": True})
                self.stats["leaves_ref_only"] += 1
                continue
            data = _serialize_leaf(leaf)
            full_writes.append((obj_name, data))
            manifest["leaves"].append({"key": key, "object": obj_name, "ref": False})
        mbytes = json.dumps(manifest).encode()
        # One batched write transaction for all full leaves + the manifest,
        # riding the cross-object coalesced transport path: one ChunkOpBatch
        # unicast per storage node for the WHOLE checkpoint, and chunks
        # shared between leaves (replicated experts, tied embeddings) ship
        # their bytes once — later leaves ride ref-only ops. write_objects
        # commits items in order and raises at the first failure, so the
        # writes_ok delta counts exactly the committed leaves — including on
        # a mid-batch failure.
        # With ``wave_bytes`` set the session streams the batch in bounded
        # waves instead (chunk+fingerprint wave k+1 while wave k's batches
        # are on the wire; O(wave) host dirty bytes), and a configured
        # presence cache elides CIT probes for chunks repeated across saves.
        writer = (
            self.session.put_many
            if self.session is not None
            else self.cluster.write_objects
        )
        ok_before = self.cluster.stats.writes_ok
        try:
            writer(
                full_writes + [(f"{self.cfg.prefix}/{name}/MANIFEST", mbytes)]
            )
        finally:
            committed = min(self.cluster.stats.writes_ok - ok_before, len(full_writes))
            self.stats["leaves_written"] += committed
            self.stats["bytes_sent"] += sum(len(d) for _, d in full_writes[:committed])
        # drain async flag flips (the paper's consistency manager)
        self.cluster.tick(2)
        return manifest

    def _batch_device_fps(self, leaves: list[tuple[str, Any]]) -> dict[str, bytes]:
        """Chunk + fingerprint every array leaf of the wave on device —
        with ``device_cdc`` the whole pytree goes through ONE fused CDC
        launch plus ONE fingerprint launch (content-defined chunks); without
        it, fixed-size chunking in one fingerprint launch. Returns leafpath
        -> raw fingerprint bytes; empty on any failure (callers fall back to
        the per-leaf path)."""
        if not self.cfg.device_fp_fastpath:
            return {}
        arr = [(k, leaf) for k, leaf in leaves if hasattr(leaf, "dtype")]
        if not arr:
            return {}
        before = kops.launch_snapshot()
        try:
            if self.spec.kind == "cdc":
                out = self._fused_device_fps([leaf for _, leaf in arr])
            else:
                fps = kops.fingerprint_tensor_chunks_many(
                    [leaf for _, leaf in arr], self.spec.target_bytes
                )
                out = [np.asarray(jax.device_get(f)).tobytes() for f in fps]
            return {k: fp for (k, _), fp in zip(arr, out)}
        except Exception:
            return {}
        finally:
            after = kops.launch_snapshot()
            self.stats["cdc_launches"] += after["cdc"] - before["cdc"]
            self.stats["fp_launches"] += after["fingerprint"] - before["fingerprint"]

    def _fused_device_fps(self, tensors: list[Any]) -> list[bytes]:
        """One fused chunk+fingerprint wave over every tensor's byte stream.
        Per-leaf fingerprint bytes = the concatenated per-chunk device
        fingerprints (CDC chunk boundaries, so any content change perturbs
        both the chunking and the fingerprints)."""
        streams = [kops.tensor_to_u8(t) for t in tensors]
        res = kops.cdc_cut_and_fingerprint_many(streams, spec=self.spec)
        out: list[bytes] = []
        for _, _, fps, n_chunks in res:
            nc = int(jax.device_get(n_chunks))
            out.append(np.asarray(jax.device_get(fps))[:nc].tobytes())
        return out

    def _ref_write(self, key: str, leaf, obj_name: str, fp_bytes: bytes | None = None) -> bool:
        """Device-fp fast path: if the tensor is unchanged since the last
        save (per the Pallas fingerprint kernel), create the new object as a
        reference-only write against the previous one — refcount unicasts,
        zero data motion. Returns True on success."""
        if not self.cfg.device_fp_fastpath or not hasattr(leaf, "dtype"):
            return False
        if fp_bytes is None:
            try:
                fps = kops.fingerprint_tensor_chunks(leaf, self.spec.target_bytes)
                fp_bytes = np.asarray(jax.device_get(fps)).tobytes()
            except Exception:
                return False
        prev = self._last_device_fps.get(key)
        self._last_device_fps[key] = (fp_bytes, obj_name)
        if prev is None or prev[0] != fp_bytes:
            return False
        ofp = self.cluster.write_object_by_ref(obj_name, prev[1])
        return ofp is not None

    # --------------------------------------------------------------- restore
    def restore(self, name: str, like: Any | None = None) -> Any:
        mbytes = self.cluster.read_object(f"{self.cfg.prefix}/{name}/MANIFEST")
        manifest = json.loads(mbytes.decode())
        # One coalesced restore for every leaf: leaves sharing chunks (the
        # dedup win this checkpointer exists for) are fetched once per
        # batch, and each node serves its chunks in one ChunkReadBatch.
        ents = manifest["leaves"]
        blobs = self.cluster.read_objects([ent["object"] for ent in ents])
        leaves = {
            ent["key"]: _deserialize_leaf(data)
            for ent, data in zip(ents, blobs)
        }
        if like is None:
            return leaves
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        out = []
        for path, leaf in flat:
            key = "/".join(str(p) for p in path)
            if key not in leaves:
                raise ReadError(f"checkpoint {name} missing leaf {key}")
            out.append(leaves[key])
        return jax.tree_util.tree_unflatten(treedef, out)

    def delete(self, name: str) -> None:
        mbytes = self.cluster.read_object(f"{self.cfg.prefix}/{name}/MANIFEST")
        manifest = json.loads(mbytes.decode())
        # ref'd objects belong to an earlier checkpoint; delete only our own
        own = {e["object"] for e in manifest["leaves"] if not e.get("ref")}
        for obj in own:
            self.cluster.delete_object(obj)
        self.cluster.delete_object(f"{self.cfg.prefix}/{name}/MANIFEST")

    def list_checkpoints(self) -> list[str]:
        names = set()
        for node in self.cluster.nodes.values():
            for name in node.shard.omap:
                if name.startswith(self.cfg.prefix + "/") and name.endswith("/MANIFEST"):
                    names.add(name.split("/")[1])
        return sorted(names)
