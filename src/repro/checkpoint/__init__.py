from repro.checkpoint.dedup_ckpt import CheckpointConfig, DedupCheckpointer

__all__ = ["CheckpointConfig", "DedupCheckpointer"]
