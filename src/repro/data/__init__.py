from repro.data.pipeline import (
    DedupWorkload,
    SyntheticLMData,
    make_dedup_objects,
)

__all__ = ["DedupWorkload", "SyntheticLMData", "make_dedup_objects"]
