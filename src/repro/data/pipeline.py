"""Data pipelines.

* SyntheticLMData — deterministic token batches for training (host-sharded
  in real deployments; here a single-process generator with per-step seeds,
  so every data-parallel worker derives its shard from (step, worker_id)
  without coordination — the shared-nothing property again).
* make_dedup_objects — FIO-style object workload with a controlled dedup
  percentage, used by the paper-reproduction benchmarks (Fig 4b, 5a, Tab 2).
"""

from __future__ import annotations

import dataclasses
import hashlib

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticLMData:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(np.uint64(self.seed * 1_000_003 + step))
        toks = rng.integers(0, self.vocab, size=(self.global_batch, self.seq_len + 1), dtype=np.int64)
        # learnable structure: half the positions follow next = prev + 1
        # (mod vocab) — a strong local rule any LM can pick up in tens of steps
        rep = rng.random((self.global_batch, self.seq_len + 1)) < 0.5
        succ = (toks[:, :-1] + 1) % self.vocab
        toks[:, 1:][rep[:, 1:]] = succ[rep[:, 1:]]
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }

    def host_shard(self, step: int, worker: int, n_workers: int) -> dict[str, np.ndarray]:
        b = self.batch(step)
        per = self.global_batch // n_workers
        sl = slice(worker * per, (worker + 1) * per)
        return {k: v[sl] for k, v in b.items()}


@dataclasses.dataclass(frozen=True)
class DedupWorkload:
    """FIO `dedupe_percentage`-style: each object is composed of blocks; a
    `dedup_pct` fraction of blocks is drawn from a small shared pool."""

    object_size: int
    n_objects: int
    dedup_pct: float        # 0..100, fraction of duplicate blocks
    block_size: int = 4096
    pool_blocks: int = 64
    seed: int = 0


def make_dedup_objects(w: DedupWorkload) -> list[tuple[str, bytes]]:
    rng = np.random.default_rng(w.seed)
    pool = [rng.bytes(w.block_size) for _ in range(w.pool_blocks)]
    objs: list[tuple[str, bytes]] = []
    blocks_per_obj = max(1, w.object_size // w.block_size)
    for i in range(w.n_objects):
        parts = []
        for _ in range(blocks_per_obj):
            if rng.random() * 100.0 < w.dedup_pct:
                parts.append(pool[rng.integers(0, w.pool_blocks)])
            else:
                parts.append(rng.bytes(w.block_size))
        data = b"".join(parts)[: w.object_size]
        name = f"obj-{w.seed}-{i}-{hashlib.md5(data[:64]).hexdigest()[:8]}"
        objs.append((name, data))
    return objs
