"""Public jit'd wrappers over the dedup kernels.

On TPU these call the Pallas kernels compiled; everywhere else they run the
kernels in interpret mode (bit-identical) or fall back to the jnp oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.chunking import GEAR_TABLE
from repro.core.fingerprint import Fingerprint, device_fp
from repro.kernels import ref
from repro.kernels.cdc import cdc_cut_masks_pallas, cdc_hashes_pallas
from repro.kernels.fingerprint import fingerprint_chunks_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# Semantic launch counters: one increment per wrapper call = one kernel
# launch on the TPU route (the jnp fallbacks count identically so the
# one-launch-per-wave contract is assertable everywhere). Python-side on
# purpose: increments happen per *call*, not per trace.
launch_counts = {"cdc": 0, "fingerprint": 0}


def _count_launch(kind: str) -> None:
    launch_counts[kind] += 1


def launch_snapshot() -> dict[str, int]:
    """Copy of the cumulative launch counters (for delta accounting)."""
    return dict(launch_counts)


def fingerprint_chunks(words: jnp.ndarray, *, use_pallas: bool | None = None) -> jnp.ndarray:
    """(n_chunks, n_words) uint32 -> (n_chunks, 4) uint32."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    _count_launch("fingerprint")
    if use_pallas:
        return fingerprint_chunks_pallas(words)
    return ref.fingerprint_chunks(words)


@functools.partial(jax.jit, static_argnames=("chunk_words", "use_pallas"))
def _fingerprint_tensor_impl(flat_u32, *, chunk_words: int, use_pallas: bool):
    n = flat_u32.shape[0]
    pad = (-n) % chunk_words
    w = jnp.pad(flat_u32, (0, pad)).reshape(-1, chunk_words)
    if use_pallas:
        return fingerprint_chunks_pallas(w)
    return ref.fingerprint_chunks(w)


def tensor_to_u32(x: jnp.ndarray) -> jnp.ndarray:
    """Bitcast any tensor to its flat little-endian uint32 stream.

    4-byte dtypes bitcast 1:1; wider dtypes (f64/i64) split into itemsize//4
    words each in memory order; sub-word dtypes (u8/bf16/f16) widen by
    little-endian byte packing, zero-padded to a word multiple. Matches
    ``np.frombuffer(arr.tobytes() + pad, "<u4")`` on the same values.
    """
    flat = x.reshape(-1)
    nbytes = flat.dtype.itemsize
    if nbytes % 4 == 0:
        return jax.lax.bitcast_convert_type(flat, jnp.uint32).reshape(-1)
    # sub-word dtypes (u8/bf16/f16): widen via u8 packing
    as_u8 = tensor_to_u8(flat)
    pad = (-as_u8.shape[0]) % 4
    as_u8 = jnp.pad(as_u8, (0, pad))
    g = as_u8.reshape(-1, 4).astype(jnp.uint32)
    return g[:, 0] | (g[:, 1] << 8) | (g[:, 2] << 16) | (g[:, 3] << 24)


def tensor_to_u8(x: jnp.ndarray) -> jnp.ndarray:
    """Bitcast any tensor to its flat byte stream, staying on device."""
    flat = x.reshape(-1)
    if flat.dtype == jnp.bool_:
        flat = flat.astype(jnp.uint8)
    if flat.dtype == jnp.uint8:
        return flat
    return jax.lax.bitcast_convert_type(flat, jnp.uint8).reshape(-1)


def fingerprint_tensor_chunks(
    x: jnp.ndarray, chunk_bytes: int = 512 * 1024, *, use_pallas: bool | None = None
) -> jnp.ndarray:
    """Fingerprint a tensor in chunk_bytes-sized pieces on device.

    Returns (n_chunks, 4) uint32. Used by dedup checkpointing to name chunks
    without host round-trips.
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    chunk_words = max(128, chunk_bytes // 4)
    flat = tensor_to_u32(x)
    _count_launch("fingerprint")
    return _fingerprint_tensor_impl(flat, chunk_words=chunk_words, use_pallas=use_pallas)


def fingerprint_tensor_chunks_many(
    tensors: list[jnp.ndarray],
    chunk_bytes: int = 512 * 1024,
    *,
    use_pallas: bool | None = None,
) -> list[jnp.ndarray]:
    """Batched ``fingerprint_tensor_chunks``: fingerprint every tensor's
    chunks in ONE kernel launch instead of one launch per tensor.

    Each tensor is padded to a chunk_words multiple independently (so results
    are bit-identical to per-tensor calls), the chunk rows are stacked into a
    single (total_chunks, chunk_words) matrix, and the kernel runs once.
    Returns one (n_chunks_i, 4) uint32 array per input tensor."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not tensors:
        return []
    chunk_words = max(128, chunk_bytes // 4)
    rows: list[jnp.ndarray] = []
    counts: list[int] = []
    for x in tensors:
        flat = tensor_to_u32(x)
        pad = (-flat.shape[0]) % chunk_words
        w = jnp.pad(flat, (0, pad)).reshape(-1, chunk_words)
        rows.append(w)
        counts.append(w.shape[0])
    stacked = jnp.concatenate(rows, axis=0)
    _count_launch("fingerprint")
    if use_pallas:
        fps = fingerprint_chunks_pallas(stacked)
    else:
        fps = ref.fingerprint_chunks(stacked)
    out: list[jnp.ndarray] = []
    off = 0
    for c in counts:
        out.append(fps[off : off + c])
        off += c
    return out


def device_fps_to_host(fps_u32: jnp.ndarray) -> list[Fingerprint]:
    """Convert kernel output rows into namespaced Fingerprint objects."""
    rows = np.asarray(jax.device_get(fps_u32))
    return [device_fp([int(w) for w in row]) for row in rows]


# Plain numpy constant: safe to close over from inside jit traces (a cached
# jnp array would leak a tracer when first materialized inside a trace).
_GEAR = np.array(GEAR_TABLE, dtype=np.uint32)


def _gear_jnp() -> np.ndarray:
    return _GEAR


def flash_attention(
    q: jnp.ndarray,             # (B, Sq, H, hd)
    k: jnp.ndarray,             # (B, Skv, K, hd)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    use_pallas: bool | None = None,
) -> jnp.ndarray:
    """Fused attention: Pallas kernel on TPU (K/V-resident blocking, see
    repro.kernels.flash_attn), JAX chunked-attention fallback elsewhere or
    when K/V exceed the VMEM-resident budget. Returns (B, Sq, H, hd)."""
    import math

    from repro.kernels.flash_attn import flash_attention_pallas
    from repro.models.layers import chunked_attention

    if use_pallas is None:
        use_pallas = _on_tpu() and k.shape[1] <= 24 * 1024
    if use_pallas:
        return flash_attention_pallas(q, k, v, causal=causal, window=window)
    b, sq, h, hd = q.shape
    kh = k.shape[2]
    qg = q.reshape(b, sq, kh, h // kh, hd)
    out = chunked_attention(
        qg, k, v, causal=causal, window=window, mask_offset=0,
        q_chunk=2048, kv_chunk=1024, scale=1.0 / math.sqrt(hd),
    )
    return out.reshape(b, sq, h, hd)


def cdc_window_hashes(
    data_u8: jnp.ndarray, *, use_pallas: bool | None = None
) -> jnp.ndarray:
    """(n,) uint8 byte stream -> (n,) uint32 window hashes, bit-identical to
    the host ``repro.core.chunking.window_hashes`` (and its scalar oracle).
    Device route for the vectorized chunker: Pallas on TPU, jnp elsewhere."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    tvals = jnp.take(_gear_jnp(), data_u8.astype(jnp.int32))
    _count_launch("cdc")
    if use_pallas:
        return cdc_hashes_pallas(tvals)
    return ref.cdc_hashes(tvals)


def cdc_boundaries(
    data_u8: jnp.ndarray, mask: int, *, use_pallas: bool | None = None
) -> jnp.ndarray:
    """(n,) uint8 byte stream -> (n,) bool boundary mask."""
    h = cdc_window_hashes(data_u8, use_pallas=use_pallas)
    return (h & jnp.uint32(mask)) == 0


# ---------------------------------------------------------------------------
# Device-resident CDC cut selection fused with fingerprinting: the whole
# chunk-naming stage (window hashes -> min/max-size cut selection -> per-chunk
# fingerprints) runs without leaving the device, in exactly ONE CDC launch and
# ONE fingerprint launch per wave of streams.
# ---------------------------------------------------------------------------


def fp_row_words(max_size: int) -> tuple[int, int]:
    """Fused-fingerprint row geometry for chunks up to ``max_size`` bytes.

    Returns (payload_words, padded_width). A chunk's row is its bytes packed
    little-endian into ``payload_words`` uint32 (zero-padded), the chunk's
    byte length in the word right after the payload (so zero-extended chunks
    of different lengths can never collide), then zero padding to a
    lane-aligned ``padded_width``. Fingerprint of a chunk == ``ref.
    fingerprint_chunks`` of its row — one fixed, kernel-friendly contract
    shared by the device route and the host oracle in tests.
    """
    payload = -(-max_size // 4)
    width = payload + 1
    width = width + (-width) % 128
    return payload, max(128, width)


def _max_cuts(n: int, min_size: int) -> int:
    """Static bound on the number of cuts in an n-byte stream: every cut
    advances the chunk start by at least min_size + 1 bytes."""
    return n // (min_size + 1) + 1


def _chunk_rows(stream_u8, cut_mask, *, n: int, min_size: int, max_size: int):
    """Segment-reduce one stream into fixed-width fingerprint rows.

    Returns (rows (M, width) u32, cutpos (m_cut,) i32, n_cuts i32 scalar,
    n_chunks i32 scalar) where M = _max_cuts(n) + 1 >= n_chunks; rows past
    n_chunks are garbage and must be sliced off by the caller.
    """
    row_words, width = fp_row_words(max_size)
    row_bytes = row_words * 4
    m_cut = _max_cuts(n, min_size)
    cutpos = jnp.nonzero(cut_mask, size=m_cut, fill_value=n)[0].astype(jnp.int32)
    n_cuts = jnp.sum(cut_mask).astype(jnp.int32)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), cutpos + 1])
    row_idx = jnp.arange(m_cut + 1, dtype=jnp.int32)
    cut_ext = jnp.concatenate([cutpos, jnp.full((1,), n - 1, jnp.int32)])
    ends = jnp.where(row_idx < n_cuts, cut_ext[row_idx], jnp.int32(n - 1))
    lens = jnp.clip(ends - starts + 1, 0, row_bytes)
    padded = jnp.pad(stream_u8, (0, row_bytes))
    rows_u8 = jax.vmap(
        lambda s: jax.lax.dynamic_slice(padded, (s,), (row_bytes,))
    )(jnp.clip(starts, 0, n))
    col = jnp.arange(row_bytes, dtype=jnp.int32)
    rows_u8 = jnp.where(col[None, :] < lens[:, None], rows_u8, jnp.uint8(0))
    g = rows_u8.reshape(-1, row_words, 4).astype(jnp.uint32)
    words = g[:, :, 0] | (g[:, :, 1] << 8) | (g[:, :, 2] << 16) | (g[:, :, 3] << 24)
    rows = (
        jnp.zeros((m_cut + 1, width), jnp.uint32)
        .at[:, :row_words].set(words)
        .at[:, row_words].set(lens.astype(jnp.uint32))
    )
    # Tail chunk exists unless the last cut landed exactly on byte n-1.
    n_chunks = n_cuts + (jnp.take(starts, n_cuts) < n).astype(jnp.int32)
    return rows, cutpos, n_cuts, n_chunks


@functools.partial(
    jax.jit,
    static_argnames=(
        "mask", "min_size", "max_size", "use_pallas", "interpret", "block_len"
    ),
)
def _cut_and_fp_impl(
    streams, *, mask: int, min_size: int, max_size: int, use_pallas: bool,
    interpret: bool, block_len: int,
):
    lens = [s.shape[0] for s in streams]
    tvs = [jnp.take(_gear_jnp(), s.astype(jnp.int32)) for s in streams]
    if use_pallas or interpret:
        masks = cdc_cut_masks_pallas(
            tvs, mask=mask, min_size=min_size, max_size=max_size,
            interpret=interpret, block_len=block_len,
        )
    else:
        # Per-stream hashing so each stream sees its own zero prefix window,
        # exactly like the kernel's per-stream halo.
        masks = [
            ref.cdc_cut_mask(
                (ref.cdc_hashes(tv) & jnp.uint32(mask)) == 0,
                n, min_size, max_size,
            )
            for tv, n in zip(tvs, lens)
        ]
    per_stream = [
        _chunk_rows(s, m, n=n, min_size=min_size, max_size=max_size)
        for s, m, n in zip(streams, masks, lens)
    ]
    stacked = jnp.concatenate([rows for rows, _, _, _ in per_stream])
    if use_pallas:
        fps = fingerprint_chunks_pallas(stacked)
    else:
        fps = ref.fingerprint_chunks(stacked)
    out, off = [], 0
    for rows, cutpos, n_cuts, n_chunks in per_stream:
        out.append((cutpos, n_cuts, fps[off : off + rows.shape[0]], n_chunks))
        off += rows.shape[0]
    return out


def cdc_cut_and_fingerprint_many(
    streams: list[jnp.ndarray],
    *,
    mask: int | None = None,
    min_size: int | None = None,
    max_size: int | None = None,
    spec=None,
    use_pallas: bool | None = None,
    interpret: bool = False,
    block_len: int | None = None,
) -> list[tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]]:
    """Chunk + fingerprint a wave of byte streams entirely on device.

    streams: list of (n_i,) uint8 arrays (one per tensor/object). Boundaries
    are bit-identical to ``chunk_cdc_scalar`` with the same mask/min/max;
    fingerprints follow the ``fp_row_words`` row contract. Pass either a
    ``core.chunking.ChunkSpec`` via ``spec=`` (the consolidated surface) or
    the raw mask/min_size/max_size trio (legacy spelling, kept mapped).

    Returns, per stream: (cut_positions (M,) i32 — first ``n_cuts`` valid,
    n_cuts i32 scalar, fps (R, 4) u32 — first ``n_chunks`` rows valid,
    n_chunks i32 scalar). All on device: the caller decides when to sync.
    Exactly one CDC launch + one fingerprint launch per call, regardless of
    wave size (empty streams short-circuit without a launch).
    """
    mask, min_size, max_size = _resolve_chunk_args(spec, mask, min_size, max_size)
    if use_pallas is None:
        use_pallas = _on_tpu()
    if block_len is None:
        from repro.kernels.cdc import CUT_BLOCK_LEN

        block_len = CUT_BLOCK_LEN
    assert min_size >= 1, "pass a normalized ChunkingSpec (min_size >= 1)"
    zero = jnp.zeros((), jnp.int32)
    empty = (
        jnp.zeros((0,), jnp.int32), zero, jnp.zeros((0, 4), jnp.uint32), zero
    )
    nonempty = [s for s in streams if s.shape[0] > 0]
    if not nonempty:
        return [empty for _ in streams]
    _count_launch("cdc")
    _count_launch("fingerprint")
    live = iter(
        _cut_and_fp_impl(
            tuple(nonempty), mask=mask, min_size=min_size, max_size=max_size,
            use_pallas=use_pallas, interpret=interpret, block_len=block_len,
        )
    )
    return [next(live) if s.shape[0] > 0 else empty for s in streams]


def cdc_cut_and_fingerprint(
    stream: jnp.ndarray,
    *,
    mask: int | None = None,
    min_size: int | None = None,
    max_size: int | None = None,
    spec=None,
    **kw,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Single-stream ``cdc_cut_and_fingerprint_many``."""
    return cdc_cut_and_fingerprint_many(
        [stream], mask=mask, min_size=min_size, max_size=max_size, spec=spec, **kw
    )[0]


def _resolve_chunk_args(
    spec, mask: int | None, min_size: int | None, max_size: int | None
) -> tuple[int, int, int]:
    """Map the consolidated ``ChunkSpec`` spelling onto the kernels' raw
    mask/min/max trio; explicit raw kwargs win over the spec (legacy call
    sites pass only the trio, new ones only ``spec``)."""
    if spec is not None:
        kw = spec.kernel_kwargs()
        mask = kw["mask"] if mask is None else mask
        min_size = kw["min_size"] if min_size is None else min_size
        max_size = kw["max_size"] if max_size is None else max_size
    if mask is None or min_size is None or max_size is None:
        raise TypeError("pass spec= or all of mask/min_size/max_size")
    return mask, min_size, max_size


def cdc_cut_offsets(
    data_u8: jnp.ndarray,
    *,
    mask: int | None = None,
    min_size: int | None = None,
    max_size: int | None = None,
    spec=None,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> np.ndarray:
    """Device cut selection -> host int64 cut positions (inclusive chunk
    ends, tail excluded) — the device twin of ``chunking._cdc_cuts``.
    Accepts ``spec=`` (a ``core.chunking.ChunkSpec``) or the raw trio."""
    mask, min_size, max_size = _resolve_chunk_args(spec, mask, min_size, max_size)
    if use_pallas is None:
        use_pallas = _on_tpu()
    n = int(data_u8.shape[0])
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    _count_launch("cdc")
    tvals = jnp.take(_gear_jnp(), data_u8.astype(jnp.int32))
    if use_pallas or interpret:
        m = cdc_cut_masks_pallas(
            [tvals], mask=mask, min_size=min_size, max_size=max_size,
            interpret=interpret,
        )[0]
    else:
        cand = (ref.cdc_hashes(tvals) & jnp.uint32(mask)) == 0
        m = ref.cdc_cut_mask(cand, n, min_size, max_size)
    return np.flatnonzero(np.asarray(jax.device_get(m)))
