"""Public jit'd wrappers over the dedup kernels.

On TPU these call the Pallas kernels compiled; everywhere else they run the
kernels in interpret mode (bit-identical) or fall back to the jnp oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.chunking import GEAR_TABLE
from repro.core.fingerprint import Fingerprint, device_fp
from repro.kernels import ref
from repro.kernels.cdc import cdc_hashes_pallas
from repro.kernels.fingerprint import fingerprint_chunks_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def fingerprint_chunks(words: jnp.ndarray, *, use_pallas: bool | None = None) -> jnp.ndarray:
    """(n_chunks, n_words) uint32 -> (n_chunks, 4) uint32."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return fingerprint_chunks_pallas(words)
    return ref.fingerprint_chunks(words)


@functools.partial(jax.jit, static_argnames=("chunk_words", "use_pallas"))
def _fingerprint_tensor_impl(flat_u32, *, chunk_words: int, use_pallas: bool):
    n = flat_u32.shape[0]
    pad = (-n) % chunk_words
    w = jnp.pad(flat_u32, (0, pad)).reshape(-1, chunk_words)
    if use_pallas:
        return fingerprint_chunks_pallas(w)
    return ref.fingerprint_chunks(w)


def tensor_to_u32(x: jnp.ndarray) -> jnp.ndarray:
    """Bitcast any tensor to a flat uint32 stream (pad odd byte-width via u8)."""
    flat = x.reshape(-1)
    nbytes = flat.dtype.itemsize
    if nbytes % 4 == 0:
        per = nbytes // 4
        return jax.lax.bitcast_convert_type(flat, jnp.uint32).reshape(-1) if per == 1 else (
            jax.lax.bitcast_convert_type(flat, jnp.uint32).reshape(-1)
        )
    # sub-word dtypes (u8/bf16/f16): widen via u8 packing
    as_u8 = jax.lax.bitcast_convert_type(flat, jnp.uint8).reshape(-1)
    pad = (-as_u8.shape[0]) % 4
    as_u8 = jnp.pad(as_u8, (0, pad))
    g = as_u8.reshape(-1, 4).astype(jnp.uint32)
    return g[:, 0] | (g[:, 1] << 8) | (g[:, 2] << 16) | (g[:, 3] << 24)


def fingerprint_tensor_chunks(
    x: jnp.ndarray, chunk_bytes: int = 512 * 1024, *, use_pallas: bool | None = None
) -> jnp.ndarray:
    """Fingerprint a tensor in chunk_bytes-sized pieces on device.

    Returns (n_chunks, 4) uint32. Used by dedup checkpointing to name chunks
    without host round-trips.
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    chunk_words = max(128, chunk_bytes // 4)
    flat = tensor_to_u32(x)
    return _fingerprint_tensor_impl(flat, chunk_words=chunk_words, use_pallas=use_pallas)


def fingerprint_tensor_chunks_many(
    tensors: list[jnp.ndarray],
    chunk_bytes: int = 512 * 1024,
    *,
    use_pallas: bool | None = None,
) -> list[jnp.ndarray]:
    """Batched ``fingerprint_tensor_chunks``: fingerprint every tensor's
    chunks in ONE kernel launch instead of one launch per tensor.

    Each tensor is padded to a chunk_words multiple independently (so results
    are bit-identical to per-tensor calls), the chunk rows are stacked into a
    single (total_chunks, chunk_words) matrix, and the kernel runs once.
    Returns one (n_chunks_i, 4) uint32 array per input tensor."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not tensors:
        return []
    chunk_words = max(128, chunk_bytes // 4)
    rows: list[jnp.ndarray] = []
    counts: list[int] = []
    for x in tensors:
        flat = tensor_to_u32(x)
        pad = (-flat.shape[0]) % chunk_words
        w = jnp.pad(flat, (0, pad)).reshape(-1, chunk_words)
        rows.append(w)
        counts.append(w.shape[0])
    stacked = jnp.concatenate(rows, axis=0)
    if use_pallas:
        fps = fingerprint_chunks_pallas(stacked)
    else:
        fps = ref.fingerprint_chunks(stacked)
    out: list[jnp.ndarray] = []
    off = 0
    for c in counts:
        out.append(fps[off : off + c])
        off += c
    return out


def device_fps_to_host(fps_u32: jnp.ndarray) -> list[Fingerprint]:
    """Convert kernel output rows into namespaced Fingerprint objects."""
    rows = np.asarray(jax.device_get(fps_u32))
    return [device_fp([int(w) for w in row]) for row in rows]


_GEAR = None


def _gear_jnp() -> jnp.ndarray:
    global _GEAR
    if _GEAR is None:
        _GEAR = jnp.asarray(np.array(GEAR_TABLE, dtype=np.uint32))
    return _GEAR


def flash_attention(
    q: jnp.ndarray,             # (B, Sq, H, hd)
    k: jnp.ndarray,             # (B, Skv, K, hd)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    use_pallas: bool | None = None,
) -> jnp.ndarray:
    """Fused attention: Pallas kernel on TPU (K/V-resident blocking, see
    repro.kernels.flash_attn), JAX chunked-attention fallback elsewhere or
    when K/V exceed the VMEM-resident budget. Returns (B, Sq, H, hd)."""
    import math

    from repro.kernels.flash_attn import flash_attention_pallas
    from repro.models.layers import chunked_attention

    if use_pallas is None:
        use_pallas = _on_tpu() and k.shape[1] <= 24 * 1024
    if use_pallas:
        return flash_attention_pallas(q, k, v, causal=causal, window=window)
    b, sq, h, hd = q.shape
    kh = k.shape[2]
    qg = q.reshape(b, sq, kh, h // kh, hd)
    out = chunked_attention(
        qg, k, v, causal=causal, window=window, mask_offset=0,
        q_chunk=2048, kv_chunk=1024, scale=1.0 / math.sqrt(hd),
    )
    return out.reshape(b, sq, h, hd)


def cdc_window_hashes(
    data_u8: jnp.ndarray, *, use_pallas: bool | None = None
) -> jnp.ndarray:
    """(n,) uint8 byte stream -> (n,) uint32 window hashes, bit-identical to
    the host ``repro.core.chunking.window_hashes`` (and its scalar oracle).
    Device route for the vectorized chunker: Pallas on TPU, jnp elsewhere."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    tvals = jnp.take(_gear_jnp(), data_u8.astype(jnp.int32))
    if use_pallas:
        return cdc_hashes_pallas(tvals)
    return ref.cdc_hashes(tvals)


def cdc_boundaries(
    data_u8: jnp.ndarray, mask: int, *, use_pallas: bool | None = None
) -> jnp.ndarray:
    """(n,) uint8 byte stream -> (n,) bool boundary mask."""
    h = cdc_window_hashes(data_u8, use_pallas=use_pallas)
    return (h & jnp.uint32(mask)) == 0
