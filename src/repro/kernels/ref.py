"""Pure-jnp oracles for the dedup hot-spot kernels.

These define the *semantics*; the Pallas kernels in fingerprint.py / cdc.py
must match them bit-exactly (uint32 wrap-around arithmetic everywhere).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# 128-bit tensor fingerprint (4 x uint32 lanes).
#
# Commutative position-salted multilinear mix: for lane l,
#   h_l = finalize( sum_i mix( w_i * A_l + (pos_i + 1) * B_l ) + n * C_l )
# The sum is associative/commutative => tile-parallel with any grid order.
# mix = xorshift-multiply avalanche (murmur3-style finalizer).
# ---------------------------------------------------------------------------

LANES = 4
# Odd multipliers per lane (distinct golden-ratio-ish constants).
A = np.array([0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D, 0x27D4EB2F], dtype=np.uint32)
B = np.array([0x165667B1, 0xD3A2646D, 0xFD7046C5, 0xB55A4F09], dtype=np.uint32)
C = np.array([0x94D049BB, 0xBF58476D, 0x2545F491, 0x9E3779B9], dtype=np.uint32)


def _mix32(x: jnp.ndarray) -> jnp.ndarray:
    """Murmur3 fmix32 avalanche on uint32."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def fingerprint_chunks(words: jnp.ndarray) -> jnp.ndarray:
    """words: (n_chunks, chunk_words) uint32 -> (n_chunks, 4) uint32.

    Each row is fingerprinted independently; padding words MUST already be
    zeroed and the true length salted in by the caller (ops.py does both).
    """
    assert words.ndim == 2, words.shape
    w = words.astype(jnp.uint32)
    n_chunks, n_words = w.shape
    pos = (jnp.arange(n_words, dtype=jnp.uint32) + jnp.uint32(1))[None, :, None]
    wl = w[:, :, None]                                   # (c, w, 1)
    a = jnp.asarray(A)[None, None, :]                    # (1, 1, 4)
    b = jnp.asarray(B)[None, None, :]
    mixed = _mix32(wl * a + pos * b)                     # (c, w, 4)
    acc = jnp.sum(mixed.astype(jnp.uint32), axis=1, dtype=jnp.uint32)
    acc = acc + jnp.uint32(n_words) * jnp.asarray(C)[None, :]
    return _mix32(acc)


# ---------------------------------------------------------------------------
# Windowed gear-hash CDC boundaries.
#
#   h_i = sum_{k=0}^{W-1} table[byte_{i-k}] << k      (uint32 wrap)
#   boundary_i = (h_i & mask) == 0
#
# Matches repro.core.chunking.window_hash_at (the host path) for i >= W-1.
# ---------------------------------------------------------------------------

WINDOW = 32


def cdc_hashes(tvals: jnp.ndarray) -> jnp.ndarray:
    """tvals: (n,) uint32 gear-table values per byte -> (n,) window hashes.

    Positions i < WINDOW-1 use the short prefix window (same as host path).
    """
    t = tvals.astype(jnp.uint32)
    n = t.shape[0]
    h = jnp.zeros((n,), dtype=jnp.uint32)
    for k in range(WINDOW):
        shifted = jnp.zeros_like(t).at[k:].set(t[: n - k] if k else t)
        h = h + (shifted << jnp.uint32(k))
    return h


def cdc_boundaries(tvals: jnp.ndarray, mask: int) -> jnp.ndarray:
    return (cdc_hashes(tvals) & jnp.uint32(mask)) == 0


# ---------------------------------------------------------------------------
# Min/max-size cut selection over the candidate mask — the jnp oracle the
# fused Pallas kernel (cdc.cdc_cut_mask_pallas) must match bit-exactly, which
# in turn matches the scalar chunk_cdc_scalar loop:
#
#   start = 0
#   repeat: lo = start + min_size; stop if lo >= n
#           hard = max(lo, start + max_size - 1)
#           cut  = first candidate >= lo if <= hard else hard
#           stop if cut >= n; emit cut; start = cut + 1
# ---------------------------------------------------------------------------


def cdc_cut_mask(
    cand: jnp.ndarray, n: int, min_size: int, max_size: int
) -> jnp.ndarray:
    """(m,) bool candidate mask (positions < n beyond which it is ignored)
    -> (m,) bool cut mask, as a ``lax.while_loop`` with carry = chunk start.
    """
    assert cand.ndim == 1
    m = cand.shape[0]
    if m == 0:
        return jnp.zeros((0,), jnp.bool_)
    pos = jnp.arange(m, dtype=jnp.int32)
    cand = cand & (pos < n)
    big = jnp.int32(2**30)

    def _next_cut(sp):
        lo = sp + min_size
        hard = jnp.maximum(lo, sp + max_size - 1)
        cmin = jnp.min(jnp.where(cand & (pos >= lo), pos, big))
        return lo, jnp.minimum(cmin, hard)

    def _cond(c):
        sp, _ = c
        lo, cut = _next_cut(sp)
        return (lo < n) & (cut < n)

    def _body(c):
        sp, out = c
        _, cut = _next_cut(sp)
        return cut + 1, out | (pos == cut)

    _, out = jax.lax.while_loop(
        _cond, _body, (jnp.int32(0), jnp.zeros((m,), jnp.bool_))
    )
    return out
