"""Pallas TPU kernel: 128-bit content fingerprints for on-device tensors.

The paper's future-work item is offloading fingerprint computation to an
accelerator ("GPU for parallel fingerprint computation"); here it runs on the
TPU VPU so checkpoint/KV chunks are fingerprinted *without* leaving HBM.

Grid layout: (chunk_tiles, word_tiles). The words axis is the reduction axis;
the commutative position-salted mix (see ref.py) makes grid-order-independent
accumulation legal. Each step loads a (TC, TW) uint32 tile into VMEM,
mixes it against the 4 lane constants, and accumulates into the (TC, 4)
output block, which stays resident in VMEM across the word_tiles loop
(output BlockSpec indexes only the chunk axis).

VMEM budget per step: TC*TW*4 B input + TC*TW*4*... intermediates. With the
default TC=256, TW=512: 512 KB input tile + ~2 MB mixed intermediate (4
lanes) — comfortably inside the ~16 MB/core VMEM, leaving room for
double-buffering the next input tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import A, B, C, LANES

# Tile sizes: TC chunks x TW words. Lane dim (128) aligned; TW multiple of
# 128 keeps loads in full VREG rows.
TILE_CHUNKS = 256
TILE_WORDS = 512


def _mix32_k(x):
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def _fingerprint_kernel(w_ref, out_ref, *, n_words_total: int, tile_words: int):
    """One grid step: accumulate lane sums for a (TC, TW) word tile."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    w = w_ref[...].astype(jnp.uint32)                     # (TC, TW)
    tc, tw = w.shape
    # Global word positions for this tile (1-based salt).
    pos = (
        jax.lax.broadcasted_iota(jnp.uint32, (tc, tw), 1)
        + jnp.uint32(1)
        + j.astype(jnp.uint32) * jnp.uint32(tile_words)
    )
    # Zero-padding words beyond n_words_total contribute mix(0*A + pos*B),
    # which is NOT zero — mask them out to match ref on exact shapes.
    valid = pos <= jnp.uint32(n_words_total)
    acc = out_ref[...]
    for lane in range(LANES):
        mixed = _mix32_k(w * jnp.uint32(int(A[lane])) + pos * jnp.uint32(int(B[lane])))
        mixed = jnp.where(valid, mixed, jnp.uint32(0))
        acc = acc.at[:, lane].set(acc[:, lane] + jnp.sum(mixed, axis=1, dtype=jnp.uint32))
    out_ref[...] = acc

    @pl.when(j == pl.num_programs(1) - 1)
    def _finalize():
        fin = out_ref[...]
        tc_out = fin.shape[0]
        # Length salt per lane (scalar constants — no captured arrays).
        lane_idx = jax.lax.broadcasted_iota(jnp.int32, (tc_out, LANES), 1)
        salt = jnp.zeros((tc_out, LANES), jnp.uint32)
        for lane in range(LANES):
            salt = jnp.where(
                lane_idx == lane,
                jnp.uint32(n_words_total) * jnp.uint32(int(C[lane])),
                salt,
            )
        out_ref[...] = _mix32_k(fin + salt)


@functools.partial(jax.jit, static_argnames=("interpret", "tile_chunks", "tile_words"))
def fingerprint_chunks_pallas(
    words: jnp.ndarray,
    *,
    interpret: bool = False,
    tile_chunks: int = TILE_CHUNKS,
    tile_words: int = TILE_WORDS,
) -> jnp.ndarray:
    """(n_chunks, n_words) uint32 -> (n_chunks, 4) uint32 fingerprints.

    Pads both axes to tile multiples; padding is masked inside the kernel so
    results are bit-identical to ref.fingerprint_chunks on the true shape.
    """
    assert words.ndim == 2, words.shape
    n_chunks, n_words = words.shape
    tc = min(tile_chunks, max(8, n_chunks))
    tw = min(tile_words, max(128, n_words))
    pc = (-n_chunks) % tc
    pw = (-n_words) % tw
    wp = jnp.pad(words.astype(jnp.uint32), ((0, pc), (0, pw)))
    grid = (wp.shape[0] // tc, wp.shape[1] // tw)

    out = pl.pallas_call(
        functools.partial(
            _fingerprint_kernel, n_words_total=n_words, tile_words=tw
        ),
        grid=grid,
        in_specs=[pl.BlockSpec((tc, tw), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((tc, LANES), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((wp.shape[0], LANES), jnp.uint32),
        interpret=interpret,
    )(wp)
    return out[:n_chunks]
