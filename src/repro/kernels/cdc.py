"""Pallas TPU kernel: windowed gear-hash CDC boundary detection.

GPU/CPU CDC rolls a hash byte-serially — useless on a vector unit. The TPU
adaptation (DESIGN.md §2) exploits that a *windowed* gear hash at position i
depends only on the previous W=32 bytes:

    h_i = sum_{k=0}^{W-1} table[byte_{i-k}] << k        (uint32 wrap)

so every position is independent: the kernel computes W shifted vector adds
per tile — pure VPU work, no sequential dependency. The wrapper does the
256-entry gear-table gather in jnp (cheap, one take()) and hands the kernel a
uint32 stream; each tile carries a W-1 halo on the left.

VMEM: tile (8, TL+31) u32 in + (8, TL) u32 out; with TL=2048 that is
~0.6 MB per step — double-buffered easily.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import WINDOW

TILE_ROWS = 8          # sublane dim
TILE_LEN = 2048        # lane dim per row


def _cdc_kernel(t_ref, out_ref):
    """t_ref: (R, TL + WINDOW - 1) halo'd table values; out: (R, TL)."""
    t = t_ref[...].astype(jnp.uint32)
    tl = out_ref.shape[1]
    h = jnp.zeros(out_ref.shape, dtype=jnp.uint32)
    # k = 0 (newest byte) lives at halo offset WINDOW-1.
    for k in range(WINDOW):
        seg = jax.lax.dynamic_slice_in_dim(t, WINDOW - 1 - k, tl, axis=1)
        h = h + (seg << jnp.uint32(k))
    out_ref[...] = h


@functools.partial(jax.jit, static_argnames=("interpret", "tile_len"))
def cdc_hashes_pallas(
    tvals: jnp.ndarray, *, interpret: bool = False, tile_len: int = TILE_LEN
) -> jnp.ndarray:
    """(n,) uint32 gear-table values -> (n,) uint32 window hashes.

    Bit-identical to ref.cdc_hashes (short windows at the stream head
    included, via zero halo).
    """
    assert tvals.ndim == 1
    n = tvals.shape[0]
    rows = TILE_ROWS
    tl = min(tile_len, max(128, n))
    per_row = tl
    n_rows = -(-n // per_row)
    n_rows_pad = (-n_rows) % rows
    total_rows = n_rows + n_rows_pad

    flat = jnp.pad(tvals.astype(jnp.uint32), (0, total_rows * per_row - n))
    body = flat.reshape(total_rows, per_row)
    # Halo: last WINDOW-1 values of the previous row (zero for row 0).
    halo_src = body[:, -(WINDOW - 1):]
    halo = jnp.concatenate(
        [jnp.zeros((1, WINDOW - 1), jnp.uint32), halo_src[:-1]], axis=0
    )
    haloed = jnp.concatenate([halo, body], axis=1)       # (rows_t, TL+W-1)

    grid = (total_rows // rows,)
    out = pl.pallas_call(
        _cdc_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, per_row + WINDOW - 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows, per_row), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((total_rows, per_row), jnp.uint32),
        interpret=interpret,
    )(haloed)
    return out.reshape(-1)[:n]


def cdc_boundaries_pallas(
    tvals: jnp.ndarray, mask: int, *, interpret: bool = False
) -> jnp.ndarray:
    return (cdc_hashes_pallas(tvals, interpret=interpret) & jnp.uint32(mask)) == 0
