"""Pallas TPU kernels: windowed gear-hash CDC — boundary hashes AND cut
selection, fully device-resident.

GPU/CPU CDC rolls a hash byte-serially — useless on a vector unit. The TPU
adaptation (DESIGN.md §2) exploits that a *windowed* gear hash at position i
depends only on the previous W=32 bytes:

    h_i = sum_{k=0}^{W-1} table[byte_{i-k}] << k        (uint32 wrap)

so every position is independent: the kernel computes W shifted vector adds
per tile — pure VPU work, no sequential dependency. The wrapper does the
256-entry gear-table gather in jnp (cheap, one take()) and hands the kernel a
uint32 stream; each tile carries a W-1 halo on the left.

``cdc_hashes_pallas`` stops there (hashes only; host selects cuts).
``cdc_cut_masks_pallas`` fuses the whole CDC decision into ONE launch: each
grid step recomputes the tile's window hashes, derives the boundary-candidate
mask (hash & mask == 0) and then runs min/max-size cut selection as a
scan-style loop whose carry — the position after the last emitted cut — lives
in SMEM and persists across the sequential TPU grid (the ``lax.scan`` carry
idiom, block-at-a-time). Per candidate the loop does one vector min-reduce
over the tile, so cost is O(cuts_in_tile * tile); the selection is
bit-identical to the scalar oracle ``chunk_cdc_scalar`` (proof sketch in
docs/kernels.md). Streams are batched: grid = (stream, tile), the carry
resets at tile 0 of every stream and per-stream byte lengths ride in SMEM.

VMEM: hash tile (8, TL+31) u32 in + (8, TL) u32 out; with TL=2048 that is
~0.6 MB per step — double-buffered easily. The cut kernel holds one
(1, BLK+31) u32 tile plus a (1, BLK) bool mask: < 40 KB at BLK=8192.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import WINDOW

TILE_ROWS = 8          # sublane dim
TILE_LEN = 2048        # lane dim per row


def _cdc_kernel(t_ref, out_ref):
    """t_ref: (R, TL + WINDOW - 1) halo'd table values; out: (R, TL)."""
    t = t_ref[...].astype(jnp.uint32)
    tl = out_ref.shape[1]
    h = jnp.zeros(out_ref.shape, dtype=jnp.uint32)
    # k = 0 (newest byte) lives at halo offset WINDOW-1.
    for k in range(WINDOW):
        seg = jax.lax.dynamic_slice_in_dim(t, WINDOW - 1 - k, tl, axis=1)
        h = h + (seg << jnp.uint32(k))
    out_ref[...] = h


@functools.partial(jax.jit, static_argnames=("interpret", "tile_len"))
def cdc_hashes_pallas(
    tvals: jnp.ndarray, *, interpret: bool = False, tile_len: int = TILE_LEN
) -> jnp.ndarray:
    """(n,) uint32 gear-table values -> (n,) uint32 window hashes.

    Bit-identical to ref.cdc_hashes (short windows at the stream head
    included, via zero halo).
    """
    assert tvals.ndim == 1
    n = tvals.shape[0]
    rows = TILE_ROWS
    tl = min(tile_len, max(128, n))
    per_row = tl
    n_rows = -(-n // per_row)
    n_rows_pad = (-n_rows) % rows
    total_rows = n_rows + n_rows_pad

    flat = jnp.pad(tvals.astype(jnp.uint32), (0, total_rows * per_row - n))
    body = flat.reshape(total_rows, per_row)
    # Halo: last WINDOW-1 values of the previous row (zero for row 0).
    halo_src = body[:, -(WINDOW - 1):]
    halo = jnp.concatenate(
        [jnp.zeros((1, WINDOW - 1), jnp.uint32), halo_src[:-1]], axis=0
    )
    haloed = jnp.concatenate([halo, body], axis=1)       # (rows_t, TL+W-1)

    grid = (total_rows // rows,)
    out = pl.pallas_call(
        _cdc_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, per_row + WINDOW - 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows, per_row), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((total_rows, per_row), jnp.uint32),
        interpret=interpret,
    )(haloed)
    return out.reshape(-1)[:n]


def cdc_boundaries_pallas(
    tvals: jnp.ndarray, mask: int, *, interpret: bool = False
) -> jnp.ndarray:
    return (cdc_hashes_pallas(tvals, interpret=interpret) & jnp.uint32(mask)) == 0


# --------------------------------------------------------------------------
# Fused hash + min/max-size cut selection (one launch per wave of streams).
# --------------------------------------------------------------------------

CUT_BLOCK_LEN = 8192   # positions per cut-selection grid step


def _cdc_cut_kernel(
    len_ref, tile_s_ref, tile_t_ref, th_ref, out_ref, carry_ref, *,
    mask: int, min_size: int, max_size: int, block_len: int,
):
    """One grid step = one (1, BLK) tile. Streams of arbitrary (different)
    lengths are concatenated tile-row-wise, so a wave wastes at most one
    block of padding per stream instead of rectangular S x Lmax padding.

    len_ref:    (S,) int32 per-stream byte lengths, SMEM.
    tile_s_ref: (T_total,) int32 stream id of each tile row, SMEM.
    tile_t_ref: (T_total,) int32 tile index *within* its stream, SMEM.
    th_ref:     (1, BLK + W - 1) uint32 halo'd gear-table values.
    out_ref:    (1, BLK) bool cut mask.
    carry_ref:  (1,) int32 SMEM scratch — persists across the sequential
                grid; holds the start of the current chunk (last cut + 1).
    """
    g = pl.program_id(0)
    s = tile_s_ref[g]
    t = tile_t_ref[g]

    @pl.when(t == 0)
    def _reset():
        carry_ref[0] = 0

    n = len_ref[s]
    tv = th_ref[...]                                     # (1, BLK + W - 1)
    blk = block_len
    # Window hashes for this tile (same shifted-add scheme as _cdc_kernel).
    h = jnp.zeros((1, blk), dtype=jnp.uint32)
    for k in range(WINDOW):
        seg = jax.lax.dynamic_slice_in_dim(tv, WINDOW - 1 - k, blk, axis=1)
        h = h + (seg.astype(jnp.uint32) << jnp.uint32(k))
    # Stream-local positions covered by this tile, and the candidate mask.
    pos = jax.lax.broadcasted_iota(jnp.int32, (1, blk), 1) + t * blk
    cand = ((h & jnp.uint32(mask)) == 0) & (pos < n)
    blk_end = t * blk + blk - 1
    big = jnp.int32(2**30)

    # Scan carry = start of the current chunk. Invariant on tile entry: no
    # boundary candidate >= start + min_size exists before this tile (earlier
    # tiles drained themselves), so searching within the tile is exact.
    def _next_cut(sp):
        lo = sp + min_size
        hard = jnp.maximum(lo, sp + max_size - 1)
        cmin = jnp.min(jnp.where(cand & (pos >= lo), pos, big))
        return lo, jnp.minimum(cmin, hard)

    def _cond(c):
        sp, _ = c
        lo, cut = _next_cut(sp)
        return (lo < n) & (cut < n) & (cut <= blk_end)

    def _body(c):
        sp, out = c
        _, cut = _next_cut(sp)
        return cut + 1, out | (pos == cut)

    s_fin, out = jax.lax.while_loop(
        _cond, _body, (carry_ref[0], jnp.zeros((1, blk), jnp.bool_))
    )
    carry_ref[0] = s_fin
    out_ref[...] = out


def cdc_cut_masks_pallas(
    tvals_list: list[jnp.ndarray],
    *,
    mask: int,
    min_size: int,
    max_size: int,
    interpret: bool = False,
    block_len: int = CUT_BLOCK_LEN,
) -> list[jnp.ndarray]:
    """Per-stream (n_i,) uint32 gear-table values -> per-stream (n_i,) bool
    cut masks. Bit i of a stream is set iff the scalar oracle
    ``chunk_cdc_scalar`` ends a chunk at byte i.

    ONE launch for the whole wave: streams are tiled independently (so each
    keeps its own zero-prefix hash window and its own scan carry) and their
    tile rows concatenated; the grid walks all rows sequentially with the
    carry in SMEM, resetting at tile 0 of every stream.
    """
    assert tvals_list and all(t.ndim == 1 for t in tvals_list)
    assert min_size >= 1, "pass a normalized ChunkingSpec (min_size >= 1)"
    assert max_size >= min_size
    lens = [int(t.shape[0]) for t in tvals_list]
    assert all(n > 0 for n in lens), "drop empty streams before the kernel"
    blk = min(block_len, max(128, max(lens)))
    tile_s: list[int] = []
    tile_t: list[int] = []
    bodies = []
    for s, (tv, n) in enumerate(zip(tvals_list, lens)):
        t_s = -(-n // blk)
        body = jnp.pad(tv.astype(jnp.uint32), (0, t_s * blk - n)).reshape(t_s, blk)
        bodies.append(body)
        tile_s.extend([s] * t_s)
        tile_t.extend(range(t_s))
    body = jnp.concatenate(bodies)                       # (T_total, blk)
    # Left halo per tile: last W-1 values of the previous tile of the SAME
    # stream, zeros at tile 0 (short-prefix-window semantics at each
    # stream's head). tile_t == 0 marks stream starts.
    first = jnp.asarray(np.asarray(tile_t) == 0)[:, None]
    prev_tail = jnp.concatenate(
        [jnp.zeros((1, WINDOW - 1), jnp.uint32), body[:-1, -(WINDOW - 1):]]
    )
    halo = jnp.where(first, jnp.uint32(0), prev_tail)
    haloed = jnp.concatenate([halo, body], axis=1)       # (T_total, blk+W-1)

    out = pl.pallas_call(
        functools.partial(
            _cdc_cut_kernel,
            mask=mask, min_size=min_size, max_size=max_size, block_len=blk,
        ),
        grid=(len(tile_s),),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, blk + WINDOW - 1), lambda g: (g, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk), lambda g: (g, 0)),
        out_shape=jax.ShapeDtypeStruct((len(tile_s), blk), jnp.bool_),
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(
        jnp.asarray(lens, jnp.int32),
        jnp.asarray(tile_s, jnp.int32),
        jnp.asarray(tile_t, jnp.int32),
        haloed,
    )
    masks, row = [], 0
    for n in lens:
        t_s = -(-n // blk)
        masks.append(out[row : row + t_s].reshape(-1)[:n])
        row += t_s
    return masks
