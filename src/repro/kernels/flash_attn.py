"""Pallas TPU flash-attention (forward) kernel.

The JAX-level chunked attention (repro.models.layers.chunked_attention) is
the portable implementation the framework lowers everywhere; this kernel is
its TPU-native twin for the serving/prefill hot path: one fused kernel per
(batch x head, query-block) grid cell, K/V streamed from VMEM, running
max/denominator in registers — no (S, S) scores ever materialized in HBM.

Blocking / VMEM budget (v5e ~16 MB/core):
    q block: (BLK_Q, hd) bf16            = 256x128x2   =  64 KB
    k,v:     (S_kv, hd) bf16 each        = 2xS_kv x256 B
    acc/m/l: (BLK_Q, hd + 2) fp32        ~ 132 KB
K/V-resident blocking covers S_kv <= ~24k; past that the wrapper falls back
to the JAX chunked path (whose lax.scan keeps HBM traffic identical
asymptotically). GQA is zero-copy: the kv BlockSpec index_map folds the
query head onto its kv group.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLK_Q = 256
BLK_KV = 512


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, causal, window,
                  blk_kv, s_kv):
    """Grid cell: one (batch*head, q-block). K/V fully resident in VMEM."""
    q = q_ref[0].astype(jnp.float32) * scale              # (BQ, hd)
    bq, hd = q.shape
    i = pl.program_id(1)
    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, blk_kv), 0)

    m = jnp.full((bq,), -jnp.inf, jnp.float32)
    l = jnp.zeros((bq,), jnp.float32)
    acc = jnp.zeros((bq, hd), jnp.float32)

    n_kv = s_kv // blk_kv
    for j in range(n_kv):                                  # static unroll
        k_blk = k_ref[0, j * blk_kv : (j + 1) * blk_kv, :].astype(jnp.float32)
        v_blk = v_ref[0, j * blk_kv : (j + 1) * blk_kv, :].astype(jnp.float32)
        s = q @ k_blk.T                                    # (BQ, BKV)
        kpos = j * blk_kv + jax.lax.broadcasted_iota(jnp.int32, (bq, blk_kv), 1)
        ok = jnp.ones((bq, blk_kv), bool)
        if causal:
            ok = ok & (kpos <= qpos)
        if window > 0:
            ok = ok & (kpos > qpos - window)
        s = jnp.where(ok, s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[:, None])
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[:, None] + p @ v_blk
        m = m_new

    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "blk_q", "blk_kv", "interpret"),
)
def flash_attention_pallas(
    q: jnp.ndarray,             # (B, Sq, H, hd)
    k: jnp.ndarray,             # (B, Skv, K, hd)
    v: jnp.ndarray,             # (B, Skv, K, hd)
    *,
    causal: bool = True,
    window: int = 0,
    scale: float | None = None,
    blk_q: int = BLK_Q,
    blk_kv: int = BLK_KV,
    interpret: bool = False,
) -> jnp.ndarray:
    b, sq, h, hd = q.shape
    _, skv, kh, _ = k.shape
    rep = h // kh
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    bq = min(blk_q, sq)
    bkv = min(blk_kv, skv)
    assert sq % bq == 0 and skv % bkv == 0, (sq, bq, skv, bkv)

    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kh, skv, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kh, skv, hd)

    def kv_index(bh, i):
        # zero-copy GQA: query head bh -> its kv group
        return (bh // h * kh + (bh % h) // rep, 0, 0)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, causal=causal, window=window,
            blk_kv=bkv, s_kv=skv,
        ),
        grid=(b * h, sq // bq),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, i: (bh, i, 0)),
            pl.BlockSpec((1, skv, hd), kv_index),
            pl.BlockSpec((1, skv, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, i: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, hd), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)
