"""Training loop: jit/pjit train_step with grad accumulation, AdamW, and
dedup-checkpointing hooks.

build_train_step(model, opt_cfg, accum=N) returns a pure
    train_step(state, batch) -> (state, metrics)
where state = {"params", "opt"}. With accum > 1, the global batch is split
into N microbatches scanned sequentially (grads averaged) — the standard
memory/throughput trade at large global batch.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.optim import AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    accum: int = 1
    log_every: int = 10
    checkpoint_every: int = 0      # 0 = never
    opt: AdamWConfig = AdamWConfig()


def init_train_state(model, rng, opt_cfg: AdamWConfig):
    params = model.init(rng)
    return {"params": params, "opt": adamw_init(params, opt_cfg)}


def build_train_step(model, opt_cfg: AdamWConfig, accum: int = 1) -> Callable:
    def loss_fn(params, batch):
        return model.loss_fn(params, batch)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state, batch):
        params = state["params"]
        if accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def micro(carry, mb):
                gacc, lacc = carry
                (l, _m), g = grad_fn(params, mb)
                return (jax.tree.map(jnp.add, gacc, g), lacc + l), None

            micro_batches = jax.tree.map(
                lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]), batch
            )
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(micro, (zeros, 0.0), micro_batches)
            grads = jax.tree.map(lambda g: g / accum, gsum)
            loss = lsum / accum
            metrics = {"loss": loss, "aux_loss": jnp.zeros((), jnp.float32),
                       "tokens": jnp.zeros((), jnp.float32)}

        new_params, new_opt, opt_metrics = adamw_update(params, grads, state["opt"], opt_cfg)
        metrics = {**metrics, **opt_metrics, "total_loss": loss}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def train_loop(
    model,
    data,
    cfg: TrainConfig,
    rng=None,
    checkpointer=None,
    state=None,
    start_step: int = 0,
) -> tuple[Any, list[dict]]:
    """Single-host driver used by examples/ and integration tests.
    `checkpointer` is a repro.checkpoint.DedupCheckpointer (optional)."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    if state is None:
        state = init_train_state(model, rng, cfg.opt)
    step_fn = jax.jit(build_train_step(model, cfg.opt, cfg.accum))
    history = []
    for step in range(start_step, cfg.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["total_loss"])
        dt = time.perf_counter() - t0
        if step % cfg.log_every == 0 or step == cfg.steps - 1:
            history.append({"step": step, "loss": loss, "sec": dt})
        if checkpointer is not None and cfg.checkpoint_every and (step + 1) % cfg.checkpoint_every == 0:
            checkpointer.save(f"step-{step + 1}", state)
    return state, history
