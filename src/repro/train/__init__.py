from repro.train.loop import TrainConfig, build_train_step, train_loop

__all__ = ["TrainConfig", "build_train_step", "train_loop"]
