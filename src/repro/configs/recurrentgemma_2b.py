"""recurrentgemma-2b — RG-LRU + local attention hybrid, 2:1 [arXiv:2402.19427].

26L d_model=2560 10H (MQA kv=1, head_dim=256) d_ff=7680 vocab=256000.
Pattern (rglru, rglru, attn_local[2048]); 26 = 8 groups + 2 tail rglru.
Sub-quadratic: runs long_500k with O(1) recurrence state.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab=256000,
    act="gelu",
    tie_embeddings=True,
    block_pattern=("rglru", "rglru", "attn_local"),
    window=2048,
    rglru_width=2560,
    rglru_blocks=10,
    sub_quadratic=True,
).validate()
