"""Model/runtime configuration schema + the assigned input-shape sets."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any

import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                       # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    act: str = "silu"
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    # layer pattern: repeating group of block kinds
    #   attn_global | attn_local | mla | moe | mamba2 | rglru
    block_pattern: tuple[str, ...] = ("attn_global",)
    window: int = 0                   # sliding window for attn_local
    # MLA (minicpm3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    expert_d_ff: int = 0
    shared_d_ff: int = 0
    capacity_factor: float = 1.25
    moe_group: int = 1024
    # SSM / recurrence
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128
    rglru_width: int = 0
    rglru_blocks: int = 10
    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    # modality frontend stub
    frontend: str = "none"            # none | audio_stub | vision_stub
    n_frontend_tokens: int = 0        # image patch tokens (vlm)
    # capabilities
    sub_quadratic: bool = False       # may run long_500k
    has_decode: bool = True
    param_dtype: Any = jnp.bfloat16
    # training
    remat: str = "full"               # full | dots | none
    # dry-run costing: run the group loop as a Python loop instead of
    # lax.scan (XLA's cost analysis counts while bodies once; the roofline
    # extrapolates per-group deltas from unrolled 1- and 2-group variants)
    unroll_layers: bool = False
    # attention implementation: "dense" materializes (S, S) scores
    # (baseline); "chunked" is flash-style double-chunked blockwise
    # attention with O(S * kv_chunk) live memory and static banded ranges
    # for sliding-window layers (beyond-paper §Perf optimization)
    attn_impl: str = "dense"
    attn_q_chunk: int = 2048
    attn_kv_chunk: int = 1024
    # serving: KV cache quantization (w8-style kv8). int8 halves decode
    # cache bytes/memory vs bf16; symmetric fixed-point with KV_SCALE.
    kv_cache_quant: bool = False
    # serving: w8a16 weight quantization — dense 2-D weights stored int8
    # with per-tensor scales, dequantized at the matmul (halves the weight
    # stream and residency for decode)
    weight_quant: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 256 so the logits dim shards on any mesh
        axis; padded ids are masked to -inf in the loss (MaxText-style)."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def pattern_len(self) -> int:
        return len(self.block_pattern)

    @property
    def n_groups(self) -> int:
        return self.n_layers // self.pattern_len

    @property
    def tail_blocks(self) -> tuple[str, ...]:
        """Layers beyond the last full pattern group (executed unrolled)."""
        rem = self.n_layers % self.pattern_len
        return self.block_pattern[:rem]

    def validate(self) -> "ModelConfig":
        assert self.n_layers >= 1 and self.d_model > 0
        for k in self.block_pattern:
            assert k in {"attn_global", "attn_local", "mla", "moe", "mamba2", "rglru"}, k
        if "moe" in self.block_pattern:
            assert self.n_experts > 0 and self.top_k > 0 and self.expert_d_ff > 0
        if "mla" in self.block_pattern:
            assert self.kv_lora_rank > 0
        if "mamba2" in self.block_pattern:
            assert self.ssm_state > 0
        if "rglru" in self.block_pattern:
            assert self.rglru_width > 0
        if "attn_local" in self.block_pattern:
            assert self.window > 0
        return self

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        pat = self.block_pattern
        n_layers = max(len(pat), 2 if len(pat) == 1 else len(pat))
        return dataclasses.replace(
            self,
            n_layers=n_layers + (self.n_layers % self.pattern_len > 0) * len(self.tail_blocks),
            d_model=128,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            head_dim=32,
            d_ff=256 if self.d_ff else 0,
            vocab=512,
            window=min(self.window, 32) if self.window else 0,
            q_lora_rank=32 if self.q_lora_rank else 0,
            kv_lora_rank=16 if self.kv_lora_rank else 0,
            qk_nope_head_dim=16 if self.qk_nope_head_dim else 0,
            qk_rope_head_dim=8 if self.qk_rope_head_dim else 0,
            v_head_dim=16 if self.v_head_dim else 0,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            expert_d_ff=64 if self.expert_d_ff else 0,
            shared_d_ff=64 if self.shared_d_ff else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=8,
            rglru_width=64 if self.rglru_width else 0,
            rglru_blocks=4 if self.rglru_width else 10,
            n_enc_layers=min(self.n_enc_layers, 2),
            n_frontend_tokens=min(self.n_frontend_tokens, 16),
        )


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """Whether (arch, shape) is a runnable cell; reason if not."""
    s = SHAPES[shape]
    if s.kind == "decode" and not cfg.has_decode:
        return False, "encoder-only arch has no decode step"
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch; long_500k skipped (DESIGN.md §4)"
    return True, ""
