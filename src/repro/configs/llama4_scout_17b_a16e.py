"""llama4-scout-17b-a16e — MoE 16 experts top-1 + shared expert
[hf:meta-llama/Llama-4-Scout-17B-16E].

48L d_model=5120 40H (GQA kv=8) expert_d_ff=8192 vocab=202048.
Treated as full attention per the assigned config (iRoPE chunking not
assigned) -> long_500k skipped, noted in DESIGN.md §4.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=202048,
    rope_theta=5e5,
    block_pattern=("moe",),
    n_experts=16,
    top_k=1,
    n_shared_experts=1,
    expert_d_ff=8192,
    shared_d_ff=8192,
).validate()
