"""llava-next-mistral-7b — VLM, Mistral-7B backbone, anyres tiling
[hf:llava-hf/llava-v1.6-mistral-7b-hf].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000. Vision frontend is a
STUB: input_specs() provides 576 precomputed patch embeddings prepended to
the token stream; loss is computed over text positions only.
long_500k skipped (full attention backbone).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=32000,
    rope_theta=1e6,
    block_pattern=("attn_global",),
    frontend="vision_stub",
    n_frontend_tokens=576,
).validate()
