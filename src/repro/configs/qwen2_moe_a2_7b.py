"""qwen2-moe-a2.7b — 4 shared + 60 routed experts top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (kv=16) expert_d_ff=1408 vocab=151936, QKV bias.
Shared block d_ff = 4 x 1408 = 5632.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=151936,
    qkv_bias=True,
    rope_theta=1e6,
    block_pattern=("moe",),
    n_experts=60,
    top_k=4,
    n_shared_experts=4,
    expert_d_ff=1408,
    shared_d_ff=5632,
    moe_group=256,   # small groups keep dispatch FLOPs ~8% of expert FLOPs at E=60,k=4
).validate()
