"""Architecture registry: --arch <id> -> ModelConfig."""

from repro.configs.base import SHAPES, ModelConfig, ShapeSpec, shape_applicable
from repro.configs.gemma3_12b import CONFIG as _gemma3
from repro.configs.llama4_scout_17b_a16e import CONFIG as _llama4
from repro.configs.llava_next_mistral_7b import CONFIG as _llava
from repro.configs.mamba2_1_3b import CONFIG as _mamba2
from repro.configs.minicpm3_4b import CONFIG as _minicpm3
from repro.configs.qwen1_5_110b import CONFIG as _qwen110b
from repro.configs.qwen2_5_32b import CONFIG as _qwen32b
from repro.configs.qwen2_moe_a2_7b import CONFIG as _qwen_moe
from repro.configs.recurrentgemma_2b import CONFIG as _rgemma
from repro.configs.whisper_tiny import CONFIG as _whisper

ARCHS: dict[str, ModelConfig] = {
    c.arch_id: c
    for c in [
        _mamba2,
        _minicpm3,
        _qwen32b,
        _gemma3,
        _qwen110b,
        _rgemma,
        _llama4,
        _qwen_moe,
        _whisper,
        _llava,
    ]
}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]


__all__ = ["ARCHS", "SHAPES", "ModelConfig", "ShapeSpec", "get_config", "shape_applicable"]
