"""qwen1.5-110b — dense GQA flagship, QKV bias [hf:Qwen/Qwen1.5 family].

80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064.
The framework's flagship dedup-checkpointing case (~1.5 TB optimizer+param
state per checkpoint). Pure full attention: long_500k skipped.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=49152,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1e6,
    block_pattern=("attn_global",),
).validate()
