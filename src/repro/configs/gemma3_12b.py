"""gemma3-12b — 5:1 local:global attention, 128k context [hf:google/gemma-3].

48L d_model=3840 16H (GQA kv=8, head_dim=256) d_ff=15360 vocab=262144.
Pattern group = 5 sliding-window (1024) layers + 1 global layer.
long_500k runs: decode memory is dominated by the ring-buffered local layers.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab=262144,
    act="gelu",
    tie_embeddings=True,
    rope_theta=1e6,
    block_pattern=("attn_local",) * 5 + ("attn_global",),
    window=1024,
    sub_quadratic=True,
).validate()
