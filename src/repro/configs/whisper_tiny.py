"""whisper-tiny — encoder-decoder ASR backbone [arXiv:2212.04356].

4L enc + 4L dec, d_model=384 6H d_ff=1536 vocab=51865. The conv/audio
frontend is a STUB: input_specs() provides precomputed frame embeddings
(B, seq_len/2, d_model); the decoder gets seq_len/2 tokens (DESIGN.md §4).
long_500k skipped (enc-dec, bounded decoder by design).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-tiny",
    family="audio",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    act="gelu",
    tie_embeddings=True,
    enc_dec=True,
    n_enc_layers=4,
    frontend="audio_stub",
).validate()
