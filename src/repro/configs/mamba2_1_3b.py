"""mamba2-1.3b — SSD (state-space duality), attention-free [arXiv:2405.21060].

48L d_model=2048, d_ff=0 (pure Mamba-2 stack), vocab=50280, ssm_state=128.
Sub-quadratic: runs long_500k.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=64,            # SSD heads = d_inner / head_dim = 4096/64
    n_kv_heads=64,
    d_ff=0,
    vocab=50280,
    tie_embeddings=True,
    block_pattern=("mamba2",),
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=128,
    sub_quadratic=True,
).validate()
