"""minicpm3-4b — dense with Multi-head Latent Attention [hf:openbmb/MiniCPM3-4B].

62L d_model=2560 40H d_ff=6400 vocab=73448; MLA: q_lora=768, kv_lora=256,
qk_nope=64, qk_rope=32, v_head=64. Decode uses the absorbed latent cache.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab=73448,
    tie_embeddings=True,
    block_pattern=("mla",),
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_head_dim=64,
    qk_rope_head_dim=32,
    v_head_dim=64,
).validate()
