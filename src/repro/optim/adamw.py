"""AdamW with fp32 master weights, global-norm clipping, cosine schedule and
optional int8 gradient compression with error feedback.

Compression model: in a 1000-node deployment the gradient all-reduce crosses
the DCN/ICI; quantizing to int8 before reduction cuts collective bytes 4x
(bf16) at <1% accuracy cost when error feedback accumulates the residual.
Under GSPMD we express it as quantize->dequantize around the (automatic)
reduction with a persistent error buffer — the collective then carries the
quantized values (XLA reduces the dequantized tensor; byte savings are
modeled in the roofline, see EXPERIMENTS.md §Perf notes).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    compress_grads: bool = False   # int8 + error feedback


def adamw_init(params, cfg: AdamWConfig):
    f32 = lambda p: p.astype(jnp.float32)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "nu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "master": jax.tree.map(f32, params),
    }
    if cfg.compress_grads:
        state["err"] = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def _quantize_int8(g: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _compress(g: jnp.ndarray, err: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """int8 quantize with error feedback. Returns (dequantized, new_err)."""
    g = g + err
    q, scale = _quantize_int8(g)
    deq = q.astype(jnp.float32) * scale
    return deq, g - deq


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    new_err = state.get("err")
    if cfg.compress_grads:
        pairs = jax.tree.map(_compress, grads, state["err"])
        grads = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))

    gnorm = jnp.sqrt(
        sum(jnp.sum(g * g) for g in jax.tree.leaves(grads)) + 1e-16
    )
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    grads = jax.tree.map(lambda g: g * clip, grads)

    step = state["step"] + 1
    lr = _schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g, state["mu"], grads)
    nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g, state["nu"], grads)

    def upd(master, m, v):
        mh = m / b1c
        vh = v / b2c
        return master - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * master)

    master = jax.tree.map(upd, state["master"], mu, nu)
    new_params = jax.tree.map(lambda mst, p: mst.astype(p.dtype), master, params)
    new_state = {"step": step, "mu": mu, "nu": nu, "master": master}
    if cfg.compress_grads:
        new_state["err"] = new_err
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
