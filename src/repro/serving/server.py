"""Batched serving driver with cluster-wide KV prefix-cache dedup.

Single-host demo-scale driver (reduced configs) that exercises the real
logic end to end: chain-fingerprint prefix matching against the
shared-nothing block store, KV reconstruction from stored block payloads,
prefill only of the uncached suffix, greedy decode, block publication, and
pin/evict lifecycle. The production path (launch/serve.py) lowers the same
decode_step under the 512-chip mesh.
"""

from __future__ import annotations

import dataclasses
import io

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DedupCluster, Fingerprint, ReadError
from repro.serving.kv_dedup import KVBlockCache


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int = 256
    block_tokens: int = 16
    max_cached_blocks: int = 4096


def _kv_to_bytes(k: np.ndarray, v: np.ndarray) -> bytes:
    """bfloat16 has no native numpy savez support; ship uint16 views."""
    bf16 = k.dtype.name == "bfloat16"
    if bf16:
        k, v = k.view(np.uint16), v.view(np.uint16)
    buf = io.BytesIO()
    np.savez(buf, k=k, v=v, bf16=np.asarray(bf16))
    return buf.getvalue()


def _kv_from_bytes(data: bytes) -> tuple[np.ndarray, np.ndarray]:
    z = np.load(io.BytesIO(data))
    k, v = z["k"], z["v"]
    if bool(z["bf16"]):
        import ml_dtypes

        k = k.view(ml_dtypes.bfloat16)
        v = v.view(ml_dtypes.bfloat16)
    return k, v


class BatchedServer:
    """Serves a decoder LM whose every block is plain {k, v} attention
    (reduced dense configs)."""

    def __init__(self, model, params, cluster: DedupCluster, cfg: ServeConfig | None = None):
        assert not model.cfg.enc_dec and set(model.cfg.block_pattern) == {"attn_global"}, \
            "demo server supports plain global-attention decoders"
        self.model = model
        self.params = params
        self.cfg = cfg or ServeConfig()
        self.kv = KVBlockCache(cluster, self.cfg.block_tokens)
        self._decode = jax.jit(model.decode_step)

    # ------------------------------------------------------------ internals
    def _empty_caches(self):
        from repro.configs.base import ShapeSpec

        spec = ShapeSpec("serve", self.cfg.max_len, 1, "decode")
        specs = self.model.cache_specs(spec)
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            specs,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )

    def _load_prefix(self, caches, fps: list[Fingerprint]):
        """Install stored KV block payloads into the cache tensors."""
        scanned, tail = caches
        k = np.array(scanned[0]["k"])   # writable host copies
        v = np.array(scanned[0]["v"])
        bt = self.cfg.block_tokens
        for i, fp in enumerate(fps):
            bk, bv = _kv_from_bytes(self.kv.get_block(fp))
            k[:, :, i * bt : (i + 1) * bt] = bk
            v[:, :, i * bt : (i + 1) * bt] = bv
        return (
            ({"k": jnp.asarray(k), "v": jnp.asarray(v)},),
            tail,
        )

    def _publish_blocks(self, caches, tokens: list[int], start_block: int):
        """Serialize newly computed KV blocks and publish to the cluster."""
        scanned, _ = caches
        k = np.asarray(scanned[0]["k"])
        v = np.asarray(scanned[0]["v"])
        bt = self.cfg.block_tokens
        fps = self.kv.block_fps(tokens)
        new_fps, payloads = [], []
        for i in range(start_block, len(fps)):
            bk = k[:, :, i * bt : (i + 1) * bt]
            bv = v[:, :, i * bt : (i + 1) * bt]
            new_fps.append(fps[i])
            payloads.append(_kv_to_bytes(bk, bv))
        self.kv.put_blocks(new_fps, payloads)
        return fps[:start_block] + new_fps

    # --------------------------------------------------------------- public
    def handle(self, prompt: list[int], gen_tokens: int = 8) -> dict:
        """Process one request. Returns {tokens, reused_tokens, computed_tokens}."""
        assert len(prompt) + gen_tokens <= self.cfg.max_len
        n_cached, matched = self.kv.match_prefix(prompt)
        if n_cached >= len(prompt):
            # Always recompute at least the final prompt token: its logits
            # are needed to start generation (cache stores KV, not logits).
            self.kv.release_blocks(matched[-1:])
            matched = matched[:-1]
            n_cached -= self.kv.block_tokens
        caches = self._empty_caches()
        if matched:
            try:
                caches = self._load_prefix(caches, matched)
            except ReadError:
                # best-effort cache: block bytes lost (e.g. node death with
                # replicas=1) -> treat as a miss and recompute everything
                self.kv.release_blocks(matched)
                matched, n_cached = [], 0
                caches = self._empty_caches()

        # prefill the uncached suffix one token at a time (decode path),
        # so the same jitted step serves both phases.
        logits = None
        for t in range(n_cached, len(prompt)):
            tok = jnp.asarray([[prompt[t]]], jnp.int32)
            logits, caches = self._decode(self.params, caches, tok, jnp.int32(t))

        all_fps = self._publish_blocks(caches, prompt, len(matched))

        out: list[int] = []
        pos = len(prompt)
        tok_next = int(jnp.argmax(logits[0, -1])) if logits is not None else prompt[-1]
        for _ in range(gen_tokens):
            out.append(tok_next)
            tok = jnp.asarray([[tok_next]], jnp.int32)
            logits, caches = self._decode(self.params, caches, tok, jnp.int32(pos))
            tok_next = int(jnp.argmax(logits[0, -1]))
            pos += 1

        self.kv.release_blocks(all_fps)
        self.kv.evict(self.cfg.max_cached_blocks)
        return {
            "tokens": out,
            "reused_tokens": n_cached,
            "computed_tokens": len(prompt) - n_cached + gen_tokens,
        }
