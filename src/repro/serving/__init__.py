from repro.serving.kv_dedup import KVBlockCache, PrefixCacheStats
from repro.serving.server import BatchedServer, ServeConfig

__all__ = ["KVBlockCache", "PrefixCacheStats", "BatchedServer", "ServeConfig"]
