"""Cluster-wide KV prefix-cache deduplication.

Prefix caching *is* the paper's technique applied to serving state: a KV
block's identity is the chain fingerprint of its token content and every
token before it (chain_fp), so identical prefixes — across requests AND
across serving replicas — map to the same block fingerprint, are placed on
the same node of the shared-nothing block store, refcounted in a CIT and
garbage-collected through commit-flag tombstones. There is no per-block
location table: placement is a pure function of the fingerprint (the
paper's rebalancing-for-free argument, here for elastic serving pools).
"""

from __future__ import annotations

import dataclasses
import hashlib

import jax
import numpy as np

from repro.core import DedupCluster, Fingerprint, chain_fp, ReadError


@dataclasses.dataclass
class PrefixCacheStats:
    block_hits: int = 0
    block_misses: int = 0
    tokens_reused: int = 0
    tokens_computed: int = 0

    @property
    def hit_rate(self) -> float:
        t = self.block_hits + self.block_misses
        return self.block_hits / t if t else 0.0


def _token_block_fp(prev: Fingerprint | None, tokens: tuple[int, ...]) -> Fingerprint:
    raw = hashlib.sha256(np.asarray(tokens, np.int32).tobytes()).digest()[:16]
    return chain_fp(prev, Fingerprint("sha256", raw))


class KVBlockCache:
    """Content-addressed KV block store over a shared-nothing DedupCluster.

    Blocks are `block_tokens` tokens wide; the stored payload is the
    serialized per-layer KV slice for those positions.
    """

    def __init__(self, cluster: DedupCluster, block_tokens: int = 16):
        self.cluster = cluster
        self.block_tokens = block_tokens
        self.stats = PrefixCacheStats()
        self._pins: dict[Fingerprint, int] = {}   # live-request pins
        self._lru: list[Fingerprint] = []         # eviction order (oldest first)

    def block_fps(self, tokens: list[int]) -> list[Fingerprint]:
        """Chain fingerprints for every complete block of this prompt."""
        out: list[Fingerprint] = []
        prev: Fingerprint | None = None
        bt = self.block_tokens
        for i in range(0, len(tokens) - len(tokens) % bt, bt):
            fp = _token_block_fp(prev, tuple(tokens[i : i + bt]))
            out.append(fp)
            prev = fp
        return out

    def match_prefix(self, tokens: list[int]) -> tuple[int, list[Fingerprint]]:
        """Longest cached prefix. Matched blocks are pinned for the request.
        Returns (n_cached_tokens, matched fps)."""
        fps = self.block_fps(tokens)
        matched: list[Fingerprint] = []
        for fp in fps:
            if self._lookup(fp):
                matched.append(fp)
                self.stats.block_hits += 1
            else:
                self.stats.block_misses += 1
                break
        for fp in matched:
            self._pin(fp)
        self.stats.tokens_reused += len(matched) * self.block_tokens
        return len(matched) * self.block_tokens, matched

    def _pin(self, fp: Fingerprint) -> None:
        self._pins[fp] = self._pins.get(fp, 0) + 1
        if fp in self._lru:
            self._lru.remove(fp)
        self._lru.append(fp)

    def _lookup(self, fp: Fingerprint) -> bool:
        name = f"kv/{fp.hex}"
        for t in self.cluster.omap_targets(name):
            node = self.cluster.nodes[t]
            if node.alive and node.shard.omap_get(name) is not None:
                return True
        return False

    def put_blocks(self, fps: list[Fingerprint], payloads: list[bytes]) -> None:
        """Idempotent (a concurrent identical put dedups to a no-op) and
        best-effort: publication failures (dead OMAP target, mid-write node
        loss) degrade to an uncached block, never to a request failure."""
        from repro.core import WriteError

        for fp, payload in zip(fps, payloads):
            try:
                self.cluster.write_object(f"kv/{fp.hex}", payload)
                self._pin(fp)
            except WriteError:
                continue
        self.stats.tokens_computed += len(fps) * self.block_tokens

    def get_block(self, fp: Fingerprint) -> bytes:
        return self.cluster.read_object(f"kv/{fp.hex}")

    def release_blocks(self, fps: list[Fingerprint]) -> None:
        """Request finished: unpin. Blocks STAY cached for future prefix hits
        until evicted (that is the point of a prefix cache)."""
        for fp in fps:
            if fp in self._pins:
                self._pins[fp] -= 1
                if self._pins[fp] <= 0:
                    del self._pins[fp]

    def evict(self, max_blocks: int) -> int:
        """LRU-evict unpinned blocks down to max_blocks. Deleting the object
        drops chunk refcounts to 0 -> commit-flag tombstone -> the paper's GC
        reclaims the bytes (or a re-reference before GC repairs the entry)."""
        evicted = 0
        while len(self._lru) > max_blocks:
            victim = next((fp for fp in self._lru if fp not in self._pins), None)
            if victim is None:
                break
            self._lru.remove(victim)
            self.cluster.delete_object(f"kv/{victim.hex}")
            evicted += 1
        return evicted
