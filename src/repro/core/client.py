"""DedupClient — the public client session over a DedupCluster.

The session facade is the single write/read surface
(``put``/``put_many``/``get``/``get_many``/``delete``/``flush``/
``close``); the
legacy ``DedupCluster.write_object``/``write_objects`` entry points are
thin shims over a cache-disabled default session. A session owns the two
bounded caches from ``core/write_cache.py``:

* the **write-back buffer**: ``put`` accepts objects without writing
  them (returning immediately, s3ql-style); the dirty set drains on
  ``flush``/``close``/``get``/``delete``/``put_many`` or automatically
  once the buffered bytes reach ``wave_bytes``;
* the **streaming ingest planner**: ``put_many`` chunks + fingerprints
  in bounded waves (O(wave) host memory) instead of materializing the
  whole batch, handing each wave to the cluster's coalesced
  ``_write_wave`` engine — wave k is on the wire while wave k+1 chunks;
* the **presence cache** (``presence_cache`` > 0): a bounded LRU
  fingerprint set taught by acked write outcomes and by batched read
  hits (restored chunk bytes are the same positive existence evidence
  an acked write outcome is). Hits turn repeat
  chunks into presence-asserted ref-only ops — no bytes travel and no
  CIT probe is booked. A presence-enabled session registers itself on
  the transport (``extra_handlers``) under its session id and receives
  ``PresenceInvalidate`` fan-outs on delete / GC reclaim / tombstone
  reap; the handler is idempotent, so chaos redelivery is harmless, and
  a LOST invalidation only costs a fallback byte resend (see
  docs/write_cache.md for the safety argument).

Message-shape parity: a session with both caches disabled (the default,
and what the shims use) produces byte-for-byte the legacy message
sequence — same ChunkOpBatches, same lookups, same net_bytes.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.fingerprint import Fingerprint
from repro.core.messages import PresenceInvalidate
from repro.core.write_cache import PendingWrites, PresenceCache, WriteBackCache


@dataclass
class DedupClient:
    """One client session. ``presence_cache`` is the presence-LRU capacity
    in fingerprints (0 = disabled); ``wave_bytes`` bounds both the
    streaming ingest wave and the write-back buffer's auto-flush
    threshold (0 = unbounded, the legacy one-wave shape)."""

    cluster: object
    presence_cache: int = 0
    wave_bytes: int = 0
    # Transport endpoint name for everything this session sends. The
    # default keeps every legacy edge key ("client" -> node) byte-identical;
    # concurrent workload sessions open with distinct names (c0, c1, ...)
    # so per-edge stats attribute contention per client.
    src: str = "client"
    session_id: str | None = None
    closed: bool = False
    presence: PresenceCache | None = field(default=None, repr=False)
    wcache: WriteBackCache | None = field(default=None, repr=False)
    pending: PendingWrites | None = field(default=None, repr=False)
    invalidations_received: int = 0
    # Scheduled-session state: nonzero while a wave this session sent is
    # un-committed (in flight). The Scheduler's event log reads it to
    # record which sessions were concurrently in flight at each step.
    in_flight: int = 0

    def __post_init__(self) -> None:
        c = self.cluster
        self.wcache = WriteBackCache(
            c.chunking, wave_bytes=self.wave_bytes, sink=c.stats
        )
        self.pending = PendingWrites(
            flush_threshold=self.wave_bytes, on_flush=self._put_pipeline
        )
        if self.presence_cache > 0:
            self.presence = PresenceCache(self.presence_cache, sink=c.stats)
            c._register_session(self)

    # ------------------------------------------------------------- transport
    def handle(self, msg, now: int, env=None) -> str:
        """Transport delivery into the session: only ``PresenceInvalidate``
        is addressed to clients. Idempotent by construction (dropping a
        fingerprint twice is a no-op), so duplicated/reordered/late copies
        need no seen-window."""
        if isinstance(msg, PresenceInvalidate):
            self.invalidations_received += 1
            if self.presence is not None:
                self.presence.invalidate_many(msg.fps)
            return "ok"
        raise TypeError(f"client session cannot handle {type(msg).__name__}")

    # ----------------------------------------------------- presence plumbing
    # The hooks ``DedupCluster._write_wave`` calls; all three are no-ops on
    # a cache-disabled session, preserving legacy behavior exactly.
    def presence_hit(self, fp: Fingerprint) -> bool:
        return self.presence is not None and self.presence.hit(fp)

    def presence_note(self, fp: Fingerprint) -> None:
        if self.presence is not None:
            self.presence.note(fp)

    def presence_drop(self, fp: Fingerprint) -> None:
        if self.presence is not None:
            self.presence.drop(fp)

    # ------------------------------------------------------------ public API
    def put(self, name: str, data: bytes) -> None:
        """Write-back accept: buffer the object and return. The write
        happens at the next ``flush``/``close``/``put_many`` (or any read/
        delete through this session), or automatically once the buffer
        reaches ``wave_bytes``. Fingerprints surface from ``flush``."""
        self._check_open()
        self.pending.add(name, data)

    def put_many(self, items: list[tuple[str, bytes]]) -> list[Fingerprint]:
        """Synchronous batched write in bounded streaming waves; returns
        one object fingerprint per item, in order. Any buffered ``put``s
        flush first so the session's writes apply in submission order."""
        self._check_open()
        self._drain_pending()
        return self._put_pipeline(items)

    def get(self, name: str) -> bytes:
        self._check_open()
        self._drain_pending()  # read-your-writes
        return self.cluster.read_objects([name], session=self)[0]

    def get_many(self, names: list[str]) -> list[bytes]:
        """Coalesced batch restore: plan every object at once and fetch
        each node's chunks in one ``ChunkReadBatch`` unicast, with
        cross-object duplicate-fetch elision — see
        ``DedupCluster.read_objects``. Returns the objects' bytes in
        request order. Acked hits teach this session's presence cache
        (restored bytes are existence evidence, same as an acked write),
        so a restore primes subsequent ``put``s for probe elision."""
        self._check_open()
        self._drain_pending()  # read-your-writes
        return self.cluster.read_objects(list(names), session=self)

    def delete(self, name: str) -> bool:
        self._check_open()
        self._drain_pending()
        return self.cluster.delete_object(name)

    def flush(self) -> dict[str, Fingerprint]:
        """Drain the write-back buffer; returns name -> object fingerprint
        for the objects this flush wrote (last-buffered wins per name)."""
        self._check_open()
        items = self.pending.drain()
        fps = self._put_pipeline(items)
        return dict(zip((name for name, _ in items), fps))

    def close(self) -> None:
        """Flush buffered writes and unregister from the cluster. The
        session's cache counters remain folded into ``cluster.stats``."""
        if self.closed:
            return
        self._drain_pending()
        if self.presence is not None:
            self.cluster._unregister_session(self)
        self.closed = True

    # -------------------------------------------------------------- internals
    def _check_open(self) -> None:
        if self.closed:
            raise RuntimeError("DedupClient session is closed")

    def _drain_pending(self) -> None:
        if len(self.pending):
            self._put_pipeline(self.pending.drain())

    def _put_pipeline(self, items: list[tuple[str, bytes]]) -> list[Fingerprint]:
        """The batched write pipeline (moved here from the legacy
        ``DedupCluster.write_objects``). Semantically identical to looping
        ``write_object`` over ``items`` — same fingerprints, refcounts,
        OMAP state, rollback behavior and fault event points; on failure
        the exception propagates after earlier items committed, exactly
        like the loop — but vectorized and coalesced where the loop is
        serial:

        1. chunking (vectorized CDC) + fingerprinting run per bounded WAVE
           (one ``fingerprint_many`` pass per wave), so peak host memory
           is O(wave), not O(batch);
        2. chunk ops for a whole wave are grouped per target node into one
           ``ChunkOpBatch`` unicast each (cross-object coalescing), so
           control messages scale with nodes touched, not objects x nodes;
        3. a wave-local fp->first-writer cache turns chunks repeated
           *across* objects into ref-only ops, and the session's presence
           cache (when enabled) does the same across waves and batches —
           duplicate bytes never hit the wire.

        ``lookup_unicasts`` counts fingerprint lookups carried
        (batch-invariant, minus presence elisions); ``control_msgs``
        counts messages, which coalescing reduces; ``net_bytes`` can only
        shrink — for batches that commit; a mid-batch failure has already
        shipped the tail's bytes, which transport counters do not
        un-count.

        Transport-policy caveat: the coalesced ChunkOpBatch is emitted by
        the client-side ingest layer (src="client", like the read path),
        so node<->node ``partition`` policies do not sever it even though
        they would sever the serial loop's primary-routed unicasts. To
        evaluate partitions against the paper's primary-routed write
        path, set ``coalesce_batches=False`` on the cluster.
        """
        c = self.cluster
        if not items:
            return []
        batched = (
            c.batch_unicasts
            if c.batch_unicasts is not None
            else c.fault_injector is None
        )
        # A presence-enabled session routes even single objects through the
        # wave engine so every write teaches (and can consult) the cache;
        # cache-disabled sessions keep the legacy single-object branch.
        coalesce = len(items) > 1 or self.presence is not None
        if not (batched and c.coalesce_batches and coalesce):
            # Per-object path (fault injector listening / batching off /
            # single object): chunk lazily per object — peak dirty bytes
            # stay O(object) — and keep every per-chunk event window.
            out: list[Fingerprint] = []
            for name, data in items:
                _, _, chunks, fps = self.wcache.prepare(name, data)
                try:
                    out.append(c._write_prepared(name, data, chunks, fps, batched))
                finally:
                    self.wcache.release()
            return out

        # Coalesced path: bounded waves (split at wave_bytes and at name
        # repeats — every prev-object check in a wave must see committed
        # OMAP state, so a batch that rewrites a name it wrote earlier in
        # the same batch splits at the repeat).
        out = []
        for wave in self.wcache.waves(items):
            out.extend(c._write_wave(wave, session=self))
        return out

    # ------------------------------------------------------- scheduled session
    def put_wave_actor(
        self, items: list[tuple[str, bytes]], commit_sink: list | None = None
    ):
        """Resumable ``put_many``: a generator actor for the discrete-event
        ``Scheduler`` (core/simclock.py). Yields an integer tick delay
        after each wave's SEND, deferring its COMMIT until the actor is
        resumed — the window in which other sessions' actors run, so N
        sessions genuinely interleave waves on one cluster.

        Pipelining: on resume, the ``waves`` generator chunks +
        fingerprints wave k+1 FIRST (while wave k is still un-committed —
        counted in ``stats.waves_overlapped``, the PR 8 caveat closed),
        then wave k commits, then wave k+1 plans. The commit-before-plan
        order is load-bearing — a wave split at a repeated name relies on
        the previous wave's commit being visible to its plan-time lookup —
        and because chunking emits no messages, the wire sequence is
        IDENTICAL to the synchronous ``put_many`` for a single session
        (the parity pin in tests/test_workload.py). Dirty-byte accounting
        note: ``peak_dirty_bytes`` books one wave at a time even though
        overlap keeps wave k's chunks resident while k+1 chunks — the
        true pipelined peak is one send-window plus one chunking wave.

        Returns ``(fps, committed)`` via ``StopIteration.value``: the
        object fingerprints in item order, and the ``(name, version)``
        commit records the concurrent-session oracle replays.
        ``commit_sink``, when given a list, receives the same records
        incrementally as each wave commits — they survive a mid-batch
        ``WriteError`` (which a generator's return value does not), so a
        chaos-faulted run still knows exactly which objects committed
        before the failure."""
        self._check_open()
        c = self.cluster
        out: list[Fingerprint] = []
        committed = commit_sink if commit_sink is not None else []
        pending_state: dict | None = None
        try:
            for wave in self.wcache.waves(items):
                if pending_state is not None:
                    # waves() just chunked this wave while the previous one
                    # was still in flight: overlap occurred.
                    c.stats.waves_overlapped += 1
                    try:
                        out.extend(c._wave_commit(pending_state, session=self))
                    finally:
                        committed.extend(pending_state["committed"])
                        pending_state = None
                        self.in_flight = 0
                state = c._wave_plan(wave, session=self)
                c._wave_send(state, session=self)
                pending_state = state
                self.in_flight = 1
                yield 1
            if pending_state is not None:
                try:
                    out.extend(c._wave_commit(pending_state, session=self))
                finally:
                    committed.extend(pending_state["committed"])
                    pending_state = None
                    self.in_flight = 0
        finally:
            if pending_state is not None:
                # Abandoned mid-flight (generator closed, or an error before
                # the commit): drop the audit registration so the refcount
                # audit can eventually reconcile the orphaned refs.
                c.release_inflight_wave(pending_state["batch_txn"])
                self.in_flight = 0
        return out, committed
