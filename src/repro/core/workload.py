"""Multi-tenant workload generator — declarative seeded workload mixes
driven through the discrete-event Scheduler (core/simclock.py).

ROADMAP item 1: the per-edge stats and straggler-NIC model need N
concurrent clients to measure anything. A ``WorkloadSpec`` declares the
mix as data (the ``tlasica__casstor`` stress-YAML idiom: client count,
Zipf object popularity, Zipf sizes, put/get/delete mix, bursty seeded
arrivals) and ``run_workload`` compiles it into one generator actor per
client — each an independent ``DedupClient`` session with its own
transport endpoint (``c0``..``cN-1``), so per-edge accounting attributes
contention per client — then runs the Scheduler to quiescence and
reports per-client throughput, p50/p99 op latency in ticks, and
per-edge/NIC contention maxima.

Everything is deterministic given ``spec.seed``: per-client op streams
come from ``random.Random(seed*1_000_003 + client_index)``, Zipf draws
use ``random.choices`` with 1/rank^s weights (pure python floats — no
hash-order iteration anywhere), and the Scheduler's tie-breaking is
seeded. Same seed ⇒ identical event log, report and final cluster state
(pinned in tests/test_workload.py; the ``multi_tenant`` bench section
gates the report's columns at tolerance 0).

Content model: objects are concatenations of blocks drawn Zipf-skewed
from a small seeded shared pool, plus a unique tail block per (client,
op) — so cross-client dedup on hot blocks is real (FASTEN's hot-chunk
concentration) while every rewrite still changes content. Hot NAMES are
real too: clients draw object names from one shared Zipf universe, so
concurrent sessions race puts/deletes/gets on the same names — the
version-authority and response-carried-prev machinery under live fire.
"""

from __future__ import annotations

import math
import random
from dataclasses import dataclass, field

from repro.core.cluster import ReadError, WriteError
from repro.core.simclock import Scheduler


@dataclass(frozen=True)
class WorkloadOp:
    """One client operation: ``kind`` in put|get|delete; ``at`` is the
    arrival tick; ``items`` carries (name, bytes) payloads for puts
    (several for a bulk put), ``name`` the target for get/delete."""

    at: int
    kind: str
    name: str = ""
    items: tuple = ()


@dataclass(frozen=True)
class WorkloadSpec:
    """A workload mix as data. ``mix`` weights put/get/delete draws;
    ``burst_p`` is the probability an op arrives in the same burst as
    its predecessor (gap 0) instead of ``1..gap_max`` ticks later.
    ``bulk_first > 0`` makes each client's first op a bulk put of that
    many objects, streamed through ``wave_bytes``-bounded waves — the
    overlap-pipelining exercise (``stats.waves_overlapped``)."""

    clients: int = 8
    objects: int = 48                 # shared Zipf name universe o0..oN-1
    ops_per_client: int = 12
    zipf_s: float = 1.1               # name popularity skew
    size_zipf_s: float = 0.8          # size-in-blocks skew (small is common)
    size_blocks_max: int = 6
    block_bytes: int = 2048
    block_pool: int = 24              # shared content blocks (dedup source)
    mix: tuple = (("put", 0.55), ("get", 0.3), ("delete", 0.15))
    burst_p: float = 0.5
    gap_max: int = 4
    bulk_first: int = 0
    wave_bytes: int = 0
    presence_cache: int = 0
    seed: int = 0
    gc_interval: int = 0              # >0: recurring cluster.run_gc actor
    repair_interval: int = 0          # >0: recurring RepairDaemon.step actor


@dataclass
class ClientRecord:
    """Mutable per-client run record (one per actor)."""

    label: str
    ops_done: int = 0
    puts_ok: int = 0
    gets_ok: int = 0
    deletes_ok: int = 0
    not_found: int = 0                # get/delete on an absent name
    failures: int = 0                 # WriteError/ReadError under faults
    bytes_written: int = 0
    bytes_read: int = 0
    latencies: list = field(default_factory=list)   # ticks, per completed op
    # Serialization witness: (version, kind, name, data|None) per committed
    # put object / acked delete, in commit order — the oracle replays the
    # union of all clients' records sorted by version (the cluster-monotonic
    # commit authority) to reproduce the winners byte-identically.
    commits: list = field(default_factory=list)


def _zipf_weights(n: int, s: float) -> list[float]:
    return [1.0 / (rank**s) for rank in range(1, n + 1)]


def _block_pool(spec: WorkloadSpec) -> list[bytes]:
    rng = random.Random(spec.seed * 7919 + 17)
    return [rng.randbytes(spec.block_bytes) for _ in range(spec.block_pool)]


def _gen_client_ops(
    spec: WorkloadSpec, client_idx: int, pool: list[bytes]
) -> list[WorkloadOp]:
    """Compile one client's seeded op stream. Bursty arrivals: a run of
    ops lands on one tick, then a seeded gap."""
    rng = random.Random(spec.seed * 1_000_003 + client_idx)
    name_w = _zipf_weights(spec.objects, spec.zipf_s)
    size_w = _zipf_weights(spec.size_blocks_max, spec.size_zipf_s)
    block_w = _zipf_weights(spec.block_pool, spec.size_zipf_s)
    kinds = [k for k, _ in spec.mix]
    kind_w = [w for _, w in spec.mix]
    names = [f"o{i}" for i in range(spec.objects)]

    def _data(tag: int) -> bytes:
        nblocks = rng.choices(range(1, spec.size_blocks_max + 1), size_w)[0]
        body = b"".join(
            pool[i] for i in rng.choices(range(spec.block_pool), block_w, k=nblocks)
        )
        # Unique tail: rewrites change content; (client, op) disambiguates.
        return body + f"|c{client_idx}:{tag}".encode()

    ops: list[WorkloadOp] = []
    t = 0
    if spec.bulk_first > 0:
        items = tuple(
            (f"bulk-c{client_idx}-{j}", _data(10_000 + j))
            for j in range(spec.bulk_first)
        )
        ops.append(WorkloadOp(at=0, kind="put", items=items))
    for j in range(spec.ops_per_client):
        if ops:  # first op arrives at t=0 (everyone bursts at the start)
            t += 0 if rng.random() < spec.burst_p else rng.randint(1, spec.gap_max)
        kind = rng.choices(kinds, kind_w)[0]
        name = rng.choices(names, name_w)[0]
        if kind == "put":
            ops.append(WorkloadOp(at=t, kind="put", name=name,
                                  items=((name, _data(j)),)))
        else:
            ops.append(WorkloadOp(at=t, kind=kind, name=name))
    return ops


def _client_actor(cluster, client, ops: list[WorkloadOp], rec: ClientRecord):
    """One client session as a generator actor: waits out arrival gaps,
    drives puts through the resumable wave pipeline (yielding while waves
    are in flight), and books one latency sample per completed op."""
    for op in ops:
        if op.at > cluster.now:
            yield op.at - cluster.now
        try:
            if op.kind == "put":
                data_by_name = dict(op.items)
                sink: list = []
                try:
                    yield from client.put_wave_actor(
                        list(op.items), commit_sink=sink
                    )
                    rec.puts_ok += 1
                    rec.bytes_written += sum(len(d) for _, d in op.items)
                finally:
                    # Waves that committed before a mid-batch failure are
                    # real commits: the oracle must see them.
                    for name, version in sink:
                        rec.commits.append(
                            (version, "put", name, data_by_name[name])
                        )
            elif op.kind == "get":
                data = client.get(op.name)
                rec.gets_ok += 1
                rec.bytes_read += len(data)
            elif op.kind == "delete":
                if client.delete(op.name):
                    rec.deletes_ok += 1
                    # delete_object allocated exactly one txn; cooperative
                    # scheduling means nobody ran in between.
                    rec.commits.append(
                        (cluster._txn_counter, "delete", op.name, None)
                    )
                else:
                    rec.not_found += 1
            else:
                raise ValueError(f"unknown op kind {op.kind!r}")
        except ReadError:
            rec.not_found += 1
        except WriteError:
            rec.failures += 1
        rec.ops_done += 1
        # Arrival-to-completion, queueing included: an op that waited
        # behind this client's own backlog pays for it in the tail.
        rec.latencies.append(max(1, cluster.now - op.at + 1))
        yield 1
    return rec


def _pct(sorted_vals: list[int], q: float) -> int:
    """Nearest-rank percentile over pre-sorted integer samples."""
    if not sorted_vals:
        return 0
    return sorted_vals[max(0, math.ceil(q * len(sorted_vals)) - 1)]


def _edge_contention(cluster) -> dict:
    """Per-edge/NIC payload maxima (deterministic ints): the busiest
    single edge and the busiest node ingress/egress lanes — the direct
    inputs of the straggler-NIC model (benchmarks/simtime.py prices
    them; this reports them raw so core carries no bench dependency)."""
    edges = cluster.transport.edges
    busiest = 0
    ingress: dict[str, int] = {}
    egress: dict[str, int] = {}
    for (src, dst), e in edges.items():
        busiest = max(busiest, e.payload_bytes)
        egress[src] = egress.get(src, 0) + e.payload_bytes
        ingress[dst] = ingress.get(dst, 0) + e.payload_bytes
    return {
        "edges": len(edges),
        "busiest_edge_payload": busiest,
        "node_ingress_max": max(
            (ingress.get(nid, 0) for nid in cluster.nodes), default=0
        ),
        "node_egress_max": max(
            (egress.get(nid, 0) for nid in cluster.nodes), default=0
        ),
    }


def run_workload(cluster, spec: WorkloadSpec, scheduler: Scheduler | None = None) -> dict:
    """Compile ``spec`` into per-client actors, run the Scheduler to
    quiescence, close the sessions, and return the report dict:
    ``per_client`` (ops/oks/p50/p99/bytes), ``totals``, ``edges``
    (contention maxima), ``max_in_flight_sessions`` (the interleaving
    witness), ``commit_log`` (version-sorted serialization witness for
    oracle replay) and ``elapsed_ticks``. Every value is a deterministic
    function of (cluster state, spec) — the bench gates them at
    tolerance 0."""
    sched = scheduler if scheduler is not None else Scheduler(cluster, seed=spec.seed)
    pool = _block_pool(spec)
    sessions = []
    records: list[ClientRecord] = []
    start_now = cluster.now
    for i in range(spec.clients):
        label = f"c{i}"
        client = cluster.client(
            presence_cache=spec.presence_cache,
            wave_bytes=spec.wave_bytes,
            src=label,
        )
        rec = ClientRecord(label=label)
        sched.spawn(
            _client_actor(cluster, client, _gen_client_ops(spec, i, pool), rec),
            name=label,
            session=client,
        )
        sessions.append(client)
        records.append(rec)
    if spec.gc_interval > 0:
        sched.every(spec.gc_interval, cluster.run_gc, name="gc")
    if spec.repair_interval > 0:
        from repro.core.recovery import RepairDaemon

        daemon = RepairDaemon(cluster)
        sched.every(spec.repair_interval, daemon.step, name="repair")
    sched.run()
    for s in sessions:
        s.close()

    per_client = []
    all_lats: list[int] = []
    for rec in records:
        lats = sorted(rec.latencies)
        all_lats.extend(lats)
        elapsed = max(1, cluster.now - start_now)
        per_client.append({
            "client": rec.label,
            "ops": rec.ops_done,
            "puts_ok": rec.puts_ok,
            "gets_ok": rec.gets_ok,
            "deletes_ok": rec.deletes_ok,
            "not_found": rec.not_found,
            "failures": rec.failures,
            "bytes_written": rec.bytes_written,
            "bytes_read": rec.bytes_read,
            "latency_p50_ticks": _pct(lats, 0.50),
            "latency_p99_ticks": _pct(lats, 0.99),
            "throughput_bytes_per_tick": rec.bytes_written // elapsed,
        })
    all_lats.sort()
    commit_log = sorted(
        (c for rec in records for c in rec.commits), key=lambda c: c[0]
    )
    return {
        "spec_seed": spec.seed,
        "clients": spec.clients,
        "per_client": per_client,
        "totals": {
            "ops": sum(r.ops_done for r in records),
            "puts_ok": sum(r.puts_ok for r in records),
            "gets_ok": sum(r.gets_ok for r in records),
            "deletes_ok": sum(r.deletes_ok for r in records),
            "not_found": sum(r.not_found for r in records),
            "failures": sum(r.failures for r in records),
            "bytes_written": sum(r.bytes_written for r in records),
            "latency_p50_ticks": _pct(all_lats, 0.50),
            "latency_p99_ticks": _pct(all_lats, 0.99),
        },
        "edges": _edge_contention(cluster),
        "max_in_flight_sessions": sched.max_in_flight_sessions,
        "scheduler_steps": sched.steps,
        "elapsed_ticks": cluster.now - start_now,
        "commit_log": commit_log,
        # Unexpected actor deaths (anything the client actors don't model
        # as an op failure — i.e. bugs). Chaos suites assert this empty so
        # a dead client can't silently weaken their invariants.
        "actor_errors": {name: repr(e) for name, e in sched.errors.items()},
    }
