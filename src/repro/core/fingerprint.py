"""Content fingerprinting for the dedup substrate.

Two namespaces, never mixed (DESIGN.md §6.2):

* ``sha256_fp``   — host path. Canonical storage-cluster fingerprint of raw
  chunk bytes. 128-bit truncation of SHA-256 (the paper uses SHA-1; we keep
  the same 160->128-ish "content name" role with a non-broken hash).
* device fingerprints — produced by ``repro.kernels.ops.fingerprint`` (Pallas
  on TPU, jnp oracle elsewhere). Used to dedup *on-device tensors* (checkpoint
  chunks, KV blocks) without pulling bytes to the host first.

A fingerprint is an opaque ``Fingerprint`` (hashable, orderable) carrying the
namespace tag so the two can never collide in one CIT.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Iterable

FP_BITS = 128
FP_BYTES = FP_BITS // 8


@dataclass(frozen=True, order=True)
class Fingerprint:
    """128-bit content fingerprint, namespaced by its producer."""

    namespace: str  # "sha256" | "device" | "name"
    value: bytes    # exactly FP_BYTES

    def __post_init__(self) -> None:
        if len(self.value) != FP_BYTES:
            raise ValueError(f"fingerprint must be {FP_BYTES} bytes, got {len(self.value)}")

    @property
    def hex(self) -> str:
        return self.value.hex()

    def short(self) -> str:
        return f"{self.namespace}:{self.value[:6].hex()}"

    def as_int(self) -> int:
        return int.from_bytes(self.value, "big")

    def __repr__(self) -> str:  # compact in logs
        return f"fp({self.short()})"


def sha256_fp(data: bytes) -> Fingerprint:
    """Canonical chunk-content fingerprint (host storage path)."""
    return Fingerprint("sha256", hashlib.sha256(data).digest()[:FP_BYTES])


def fingerprint_many(chunks: Iterable[bytes]) -> list[Fingerprint]:
    """Batch fingerprinting: hash every chunk (of one object or of a whole
    write batch) in one pass. Results are exactly ``[sha256_fp(c) for c in
    chunks]``; batching keeps the hot write path to a single call site and
    lets the device path (``repro.kernels.ops.fingerprint_tensor_chunks_many``)
    swap in without touching callers."""
    sha = hashlib.sha256
    nb = FP_BYTES
    return [Fingerprint("sha256", sha(c).digest()[:nb]) for c in chunks]


def name_fp(name: str) -> Fingerprint:
    """Object-name fingerprint — locates the primary OSS for an object
    (the paper's 'client performs object name hashing')."""
    return Fingerprint("name", hashlib.sha256(name.encode("utf-8")).digest()[:FP_BYTES])


def device_fp(words: Iterable[int]) -> Fingerprint:
    """Wrap the 4 uint32 lanes produced by the device fingerprint kernel."""
    ws = list(words)
    if len(ws) != 4:
        raise ValueError(f"device fingerprint needs 4 u32 words, got {len(ws)}")
    raw = b"".join(int(w & 0xFFFFFFFF).to_bytes(4, "big") for w in ws)
    return Fingerprint("device", raw)


def chain_fp(parent: Fingerprint | None, child: Fingerprint) -> Fingerprint:
    """Chained fingerprint: fp(prefix chain + block). Used for KV prefix-cache
    block identity (a block's identity includes everything before it)."""
    h = hashlib.sha256()
    if parent is not None:
        h.update(parent.namespace.encode())
        h.update(parent.value)
    h.update(child.namespace.encode())
    h.update(child.value)
    return Fingerprint("chain", h.digest()[:FP_BYTES])


def object_fp(chunk_fps: list[Fingerprint]) -> Fingerprint:
    """Whole-object fingerprint = hash over the ordered chunk fingerprints
    (the paper's OMAP 'object fingerprint')."""
    h = hashlib.sha256()
    for fp in chunk_fps:
        h.update(fp.namespace.encode())
        h.update(fp.value)
    return Fingerprint("sha256", h.digest()[:FP_BYTES])
