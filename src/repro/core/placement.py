"""Content-fingerprint-based placement (CRUSH-lite).

The paper feeds the chunk's SHA-1 fingerprint into CRUSH so that the
fingerprint *alone* (plus the current cluster map) determines which storage
server holds the chunk and its CIT entry. We implement the same contract with
weighted rendezvous (HRW) hashing:

* pure function of (fingerprint, cluster_map)  -> no location metadata, ever;
* minimal movement on topology change          -> only ~1/N of chunks move;
* weight-aware                                 -> heterogeneous nodes;
* replica sets = top-K rendezvous winners      -> fault tolerance.

The cluster map is versioned (epoch) like Ceph's OSDMap, which is what makes
elastic scaling a metadata no-op for dedup (§2 of the paper / DESIGN.md §2).
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, field, replace

from repro.core.fingerprint import Fingerprint


@dataclass(frozen=True)
class ClusterMap:
    """Versioned shared-nothing cluster topology."""

    epoch: int
    nodes: tuple[str, ...]                       # node ids, "up" set
    weights: dict[str, float] = field(default_factory=dict)
    replicas: int = 1

    def weight(self, node: str) -> float:
        return self.weights.get(node, 1.0)

    def with_node(self, node: str, weight: float = 1.0) -> "ClusterMap":
        if node in self.nodes:
            raise ValueError(f"node {node} already in map")
        return ClusterMap(
            self.epoch + 1,
            self.nodes + (node,),
            {**self.weights, node: weight},
            self.replicas,
        )

    def without_node(self, node: str) -> "ClusterMap":
        if node not in self.nodes:
            raise ValueError(f"node {node} not in map")
        w = dict(self.weights)
        w.pop(node, None)
        return ClusterMap(
            self.epoch + 1,
            tuple(n for n in self.nodes if n != node),
            w,
            self.replicas,
        )

    def with_replicas(self, replicas: int) -> "ClusterMap":
        return replace(self, epoch=self.epoch + 1, replicas=replicas)


def _score(fp: Fingerprint, node: str) -> float:
    """Rendezvous score in (0,1], stable across runs (no PYTHONHASHSEED)."""
    h = hashlib.blake2s(digest_size=8)
    h.update(fp.namespace.encode())
    h.update(fp.value)
    h.update(node.encode())
    u = int.from_bytes(h.digest(), "big")
    return (u + 1) / float(1 << 64)


def place(fp: Fingerprint, cmap: ClusterMap, k: int | None = None) -> list[str]:
    """Top-k weighted-rendezvous winners for this fingerprint.

    Weighted HRW: score_n = -w_n / ln(u_n); highest wins. Equivalent to
    straw2's logarithmic straw lengths.
    """
    import math

    if not cmap.nodes:
        raise RuntimeError("empty cluster map")
    k = k or cmap.replicas
    scored = []
    for n in cmap.nodes:
        u = _score(fp, n)
        w = cmap.weight(n)
        if w <= 0:
            continue
        scored.append((-w / math.log(u) if u < 1.0 else float("inf"), n))
    scored.sort(key=lambda t: (-t[0], t[1]))
    return [n for _, n in scored[: max(1, k)]]


def primary(fp: Fingerprint, cmap: ClusterMap) -> str:
    return place(fp, cmap, 1)[0]
