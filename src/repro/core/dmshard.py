"""DM-Shard: the per-storage-server deduplication metadata shard.

Two persistent structures, exactly as in the paper (§2.2):

* OMAP — Object Map: object name -> (object fingerprint, ordered chunk-fp
  list). Holds the layout/reconstruction logic; lives on the OSS selected by
  hashing the *object name*.
* CIT — Chunk Information Table: chunk fingerprint -> (refcount, commit flag,
  size). Holds the performance-sensitive dedup metadata; lives on the OSS
  selected by hashing the *chunk content* — so every lookup is a unicast.

Commit flag semantics (tagged consistency, paper §2.4):
  flag == INVALID (0): fingerprint may not point at valid stored content —
      either the async flip hasn't happened yet, the txn crashed, or the
      refcount dropped to zero (tombstone; our reuse of the same machinery).
  flag == VALID (1): chunk bytes are guaranteed present on this server.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, field

from repro.core.fingerprint import Fingerprint, name_fp

INVALID = 0
VALID = 1


def digest_hash(fp: Fingerprint, has_bytes: bool, has_cit: bool) -> int:
    """Order-independent per-entry hash for recovery digests. Presence of
    the chunk bytes and of the CIT entry are part of the identity — two
    replicas disagree exactly when one is missing either — while refcount
    and flag are deliberately EXCLUDED: replicas legitimately diverge there
    in transit (pending async flips), and reconciling refcounts is the
    audit's job, not the digest diff's."""
    h = hashlib.blake2s(digest_size=8)
    h.update(fp.namespace.encode())
    h.update(fp.value)
    h.update(bytes((has_bytes, has_cit)))
    return int.from_bytes(h.digest(), "big")


def omap_digest_hash(
    name: str, object_fp: Fingerprint | None, deleted: bool = False
) -> int:
    """Per-entry hash for OMAP digests: the identity is (name, object
    fingerprint, tombstone marker) — replicas holding different versions
    of a name, a tombstone where a peer holds the live entry (a delete
    one replica missed), or missing the name entirely digest differently.
    A tombstone has no object fingerprint; its marker byte is the
    identity."""
    h = hashlib.blake2s(digest_size=8)
    h.update(name.encode("utf-8"))
    if object_fp is not None:
        h.update(object_fp.namespace.encode())
        h.update(object_fp.value)
    h.update(bytes((deleted,)))
    return int.from_bytes(h.digest(), "big")


@dataclass
class CITEntry:
    refcount: int = 0
    flag: int = INVALID
    size: int = 0
    # Bookkeeping for GC aging (sim time when the flag last became INVALID).
    invalid_since: int | None = None
    # Sim time of the last refcount/flag mutation. The incremental audit's
    # in-flight-transaction gate: an entry touched at or after a background
    # round's start epoch may belong to a transaction still completing, so
    # corrections for it are deferred to the next round.
    mtime: int = 0

    def is_valid(self) -> bool:
        return self.flag == VALID

    def snapshot(self) -> "CITEntry":
        """Detached copy, safe to put on the wire (rebalance/scrub)."""
        return CITEntry(
            self.refcount, self.flag, self.size, self.invalid_since, self.mtime
        )

    def clone_into(self, shard: "DMShard", fp: Fingerprint, now: int) -> "CITEntry | None":
        """Copy this entry into ``shard`` under ``fp`` unless one already
        exists there. The single place CIT entries are duplicated across
        nodes (chunk migration, stray-tombstone moves, scrub repair)."""
        if shard.cit_lookup(fp) is not None:
            return None
        e = shard.cit_insert(fp, self.size, now)
        e.refcount = self.refcount
        e.flag = self.flag
        e.invalid_since = self.invalid_since
        return e


@dataclass
class OMAPEntry:
    name: str
    object_fp: Fingerprint | None
    chunk_fps: list[Fingerprint]
    size: int
    # Commit version: the committing transaction's cluster-monotonic id.
    # Recovery's OMAP repair elects the replica holding the HIGHEST version
    # as authority — placement order alone would let a primary that was
    # down across a replace resurrect the old version cluster-wide, and a
    # per-name counter would reset on delete+recreate (letting a stale
    # higher-versioned replica overwrite the fresh entry); the txn counter
    # only ever grows, so the latest commit always wins.
    version: int = 1
    # Delete tombstone: ``deleted=True`` records that this name was deleted
    # by transaction ``version`` at sim time ``deleted_at``. The record has
    # no live recipe (object_fp None — the delete released the refs;
    # ``chunk_fps`` merely RETAINS the released fingerprints for the reap's
    # presence-invalidation fan-out and is excluded from digest identity
    # and recipe_refs) but is replicated, digested, and repaired exactly
    # like a live entry, so a replica that missed the delete adopts the
    # tombstone instead of resurrecting the name. ``deleted_at`` travels
    # with the record unchanged: a late adopter inherits the ORIGINAL
    # deletion time, so the GC horizon ages cluster-consistently.
    deleted: bool = False
    deleted_at: int | None = None


@dataclass
class DMShard:
    """One shard; hosted by exactly one StorageNode, replicated like data."""

    omap: dict[str, OMAPEntry] = field(default_factory=dict)
    cit: dict[Fingerprint, CITEntry] = field(default_factory=dict)

    # --- CIT ops (unicast targets of fingerprint-routed I/O) ---------------
    def cit_lookup(self, fp: Fingerprint) -> CITEntry | None:
        return self.cit.get(fp)

    def cit_insert(self, fp: Fingerprint, size: int, now: int) -> CITEntry:
        if fp in self.cit:
            raise KeyError(f"CIT entry exists for {fp}")
        e = CITEntry(refcount=0, flag=INVALID, size=size, invalid_since=now, mtime=now)
        self.cit[fp] = e
        return e

    def cit_set_flag(self, fp: Fingerprint, flag: int, now: int) -> None:
        e = self.cit[fp]
        if e.flag != flag:
            e.flag = flag
            e.invalid_since = now if flag == INVALID else None
            e.mtime = max(e.mtime, now)

    def cit_addref(self, fp: Fingerprint, delta: int = 1, now: int | None = None) -> int:
        e = self.cit[fp]
        e.refcount += delta
        if e.refcount < 0:
            raise AssertionError(f"negative refcount for {fp}")
        if now is not None:
            e.mtime = max(e.mtime, now)
        return e.refcount

    def cit_remove(self, fp: Fingerprint) -> None:
        del self.cit[fp]

    # --- batched CIT ops (one unicast carries many chunk ops) ---------------
    def cit_lookup_many(self, fps: list[Fingerprint]) -> list[CITEntry | None]:
        """Batched lookup — the payload of one batched unicast message."""
        cit = self.cit
        return [cit.get(fp) for fp in fps]

    def cit_insert_many(
        self, items: list[tuple[Fingerprint, int]], now: int
    ) -> list[CITEntry]:
        return [self.cit_insert(fp, size, now) for fp, size in items]

    def cit_addref_many(self, fps: list[Fingerprint], delta: int = 1) -> list[int]:
        return [self.cit_addref(fp, delta) for fp in fps]

    # --- OMAP ops (object-name-routed I/O) ----------------------------------
    def omap_put(self, entry: OMAPEntry) -> None:
        self.omap[entry.name] = entry

    def omap_apply(self, entry: OMAPEntry) -> tuple[bool, OMAPEntry | None]:
        """Version-gated put: the cluster-monotonic commit-version authority
        rule applied receiver-side. The record lands only when it is at
        least as new as what the replica holds — so a DELAYED commit
        arriving after a newer replace or a newer tombstone cannot
        resurrect the old version, and a tombstone cannot clobber a
        recreate it lost the race to. Returns ``(applied, replaced)``:
        whether the record landed, and the record it replaced (entry or
        tombstone, None when the name was absent or the put was refused).
        The replaced record rides the commit's response so the SENDER can
        release exactly the version its put displaced — under concurrent
        sessions two replacers may both have planned against the same
        previous version, and releasing the plan-time fetch twice would
        corrupt refcounts; the response-carried record is released exactly
        once, by the writer that actually displaced it."""
        cur = self.omap.get(entry.name)
        if cur is not None and cur.version > entry.version:
            return False, None
        self.omap[entry.name] = entry
        return True, cur

    def omap_get(self, name: str) -> OMAPEntry | None:
        return self.omap.get(name)

    def omap_delete(self, name: str) -> OMAPEntry | None:
        return self.omap.pop(name, None)

    def omap_tombstone(
        self, name: str, version: int, now: int
    ) -> tuple[bool, OMAPEntry | None]:
        """Commit a delete tombstone at ``version`` (the deleting txn's
        cluster-monotonic id). A strictly newer record already in place
        wins — the delete is stale — otherwise the tombstone replaces
        whatever is held (including nothing: a replica that missed the put
        entirely still records the delete, guarding against the put's late
        copy). Returns ``(applied, previous_entry)``; the previous LIVE
        entry rides the response into the sender's seen-window so a
        cancelled delete can restore it.

        The tombstone RETAINS the replaced recipe's chunk fingerprints
        (``chunk_fps``; carried forward from a previous tombstone on
        re-delete). They are not part of the digest identity and
        ``recipe_refs`` still skips tombstones — the recipe is released —
        but the reap can then return them, giving presence caches a
        last-chance invalidation for deletes whose original fan-out was
        lost (e.g. across a partition)."""
        prev = self.omap.get(name)
        if prev is not None and prev.version > version:
            return False, None
        retained = list(prev.chunk_fps) if prev is not None else []
        self.omap[name] = OMAPEntry(
            name, None, retained, 0, version, deleted=True, deleted_at=now
        )
        return True, prev

    def omap_reap(self, name: str, version: int) -> OMAPEntry | None:
        """GC-horizon reap: remove the tombstone record iff the held entry
        is a tombstone at exactly ``version`` (a newer write or delete is
        untouched). Idempotent — the coordinator only sends this once every
        live placement target proved it holds the aged tombstone. Returns
        the reaped record (its retained ``chunk_fps`` ride the response,
        feeding the coordinator's presence-invalidation fan-out) or None
        when nothing was reaped."""
        cur = self.omap.get(name)
        if cur is None or not cur.deleted or cur.version != version:
            return None
        del self.omap[name]
        return cur

    def aged_tombstones(self, now: int, horizon: int) -> dict[str, tuple[int, int]]:
        """Tombstones past the GC horizon (name -> (version, deleted_at)) —
        this node's reap candidates, listed in omap digest summary replies
        so the coordinator can check cluster-wide full-ack before reaping."""
        return {
            name: (e.version, e.deleted_at)
            for name, e in self.omap.items()
            if e.deleted and e.deleted_at is not None
            and now - e.deleted_at >= horizon
        }

    # --- recovery digests (per-placement-group content summaries) -----------
    def chunk_digest(
        self,
        chunk_store: dict[Fingerprint, bytes],
        cmap,
        groups: tuple = (),
        detail_all: bool = False,
        only_groups: set | None = None,
        summary_only: bool = False,
    ) -> tuple[dict, dict, int]:
        """Digest THIS shard's chunk/CIT holdings, grouped by the placement
        tuple each fingerprint hashes to under ``cmap``. Returns
        ``(summary, entries, skipped)``: summary maps group ->
        (count, xor-hash); entries (detail mode: ``groups`` named or
        ``detail_all``) map fp -> (has_bytes, has_cit, refcount, flag,
        size, mtime). With ``only_groups`` (the node's dirty set for an
        incremental probe) summaries cover just those groups and
        ``skipped`` counts the clean groups left un-digested;
        ``summary_only`` restricts summaries to the named ``groups``
        without expanding detail. Strictly node-local — the wire view of
        this node a recovery coordinator reconciles against."""
        from repro.core.placement import place

        want = set(groups)
        detail = not summary_only and (detail_all or bool(want))
        summary: dict = {}
        entries: dict = {}
        skipped: set = set()
        for fp in set(self.cit) | set(chunk_store):
            g = tuple(place(fp, cmap))
            if not detail:
                if summary_only and g not in want:
                    continue
                if only_groups is not None and g not in only_groups:
                    skipped.add(g)
                    continue
                cnt, xo = summary.get(g, (0, 0))
                summary[g] = (cnt + 1, xo ^ digest_hash(fp, fp in chunk_store, fp in self.cit))
                continue
            if not detail_all and g not in want:
                continue
            e = self.cit.get(fp)
            entries[fp] = (
                fp in chunk_store,
                e is not None,
                e.refcount if e is not None else 0,
                e.flag if e is not None else INVALID,
                e.size if e is not None else 0,
                e.mtime if e is not None else 0,
            )
        return summary, entries, len(skipped)

    def omap_digest(
        self,
        cmap,
        groups: tuple = (),
        detail_all: bool = False,
        only_groups: set | None = None,
        summary_only: bool = False,
    ) -> tuple[dict, dict, int]:
        """Digest THIS shard's OMAP entries (tombstones included — a
        tombstone digests differently from the live entry it replaced and
        from absence, which is exactly what lets repair propagate deletes),
        grouped by object-name placement. Detail entries map name ->
        (object fingerprint, commit version, deleted, deleted_at) — the
        identity and authority a repair needs to pick a holder; the recipe
        itself travels with the repairing ``OmapPut``, not with the
        digest. ``only_groups`` / ``summary_only`` as in
        ``chunk_digest``; returns ``(summary, entries, skipped)``."""
        from repro.core.placement import place

        want = set(groups)
        detail = not summary_only and (detail_all or bool(want))
        summary: dict = {}
        entries: dict = {}
        skipped: set = set()
        for name, e in self.omap.items():
            g = tuple(place(name_fp(name), cmap))
            if not detail:
                if summary_only and g not in want:
                    continue
                if only_groups is not None and g not in only_groups:
                    skipped.add(g)
                    continue
                cnt, xo = summary.get(g, (0, 0))
                summary[g] = (cnt + 1, xo ^ omap_digest_hash(name, e.object_fp, e.deleted))
            elif detail_all or g in want:
                entries[name] = (e.object_fp, e.version, e.deleted, e.deleted_at)
        return summary, entries, len(skipped)

    def recipe_refs(self, cmap, live: tuple, self_id: str) -> dict[Fingerprint, int]:
        """Aggregated chunk-reference counts from the recipes this node
        OWNS: it is the first live name-hash target of the entry under
        ``cmap`` given the coordinator's ``live`` set — so across the
        cluster every logical object is counted by exactly one owner, even
        though OMAP entries are replicated. Occurrences count: an object
        whose recipe repeats a chunk took one reference per occurrence.
        Tombstones carry no recipe (the delete released the refs) and are
        skipped."""
        from repro.core.placement import place

        live_set = set(live)
        counts: dict[Fingerprint, int] = {}
        for name, e in self.omap.items():
            if e.deleted:
                continue
            owner = next(
                (t for t in place(name_fp(name), cmap) if t in live_set), None
            )
            if owner != self_id:
                continue
            for fp in e.chunk_fps:
                counts[fp] = counts.get(fp, 0) + 1
        return counts

    # --- introspection -------------------------------------------------------
    def stored_bytes(self) -> int:
        return sum(e.size for e in self.cit.values())

    def valid_bytes(self) -> int:
        return sum(e.size for e in self.cit.values() if e.is_valid())

    def invalid_fps(self) -> list[Fingerprint]:
        return [fp for fp, e in self.cit.items() if e.flag == INVALID]
