"""DM-Shard: the per-storage-server deduplication metadata shard.

Two persistent structures, exactly as in the paper (§2.2):

* OMAP — Object Map: object name -> (object fingerprint, ordered chunk-fp
  list). Holds the layout/reconstruction logic; lives on the OSS selected by
  hashing the *object name*.
* CIT — Chunk Information Table: chunk fingerprint -> (refcount, commit flag,
  size). Holds the performance-sensitive dedup metadata; lives on the OSS
  selected by hashing the *chunk content* — so every lookup is a unicast.

Commit flag semantics (tagged consistency, paper §2.4):
  flag == INVALID (0): fingerprint may not point at valid stored content —
      either the async flip hasn't happened yet, the txn crashed, or the
      refcount dropped to zero (tombstone; our reuse of the same machinery).
  flag == VALID (1): chunk bytes are guaranteed present on this server.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.fingerprint import Fingerprint

INVALID = 0
VALID = 1


@dataclass
class CITEntry:
    refcount: int = 0
    flag: int = INVALID
    size: int = 0
    # Bookkeeping for GC aging (sim time when the flag last became INVALID).
    invalid_since: int | None = None

    def is_valid(self) -> bool:
        return self.flag == VALID

    def snapshot(self) -> "CITEntry":
        """Detached copy, safe to put on the wire (rebalance/scrub)."""
        return CITEntry(self.refcount, self.flag, self.size, self.invalid_since)

    def clone_into(self, shard: "DMShard", fp: Fingerprint, now: int) -> "CITEntry | None":
        """Copy this entry into ``shard`` under ``fp`` unless one already
        exists there. The single place CIT entries are duplicated across
        nodes (chunk migration, stray-tombstone moves, scrub repair)."""
        if shard.cit_lookup(fp) is not None:
            return None
        e = shard.cit_insert(fp, self.size, now)
        e.refcount = self.refcount
        e.flag = self.flag
        e.invalid_since = self.invalid_since
        return e


@dataclass
class OMAPEntry:
    name: str
    object_fp: Fingerprint
    chunk_fps: list[Fingerprint]
    size: int


@dataclass
class DMShard:
    """One shard; hosted by exactly one StorageNode, replicated like data."""

    omap: dict[str, OMAPEntry] = field(default_factory=dict)
    cit: dict[Fingerprint, CITEntry] = field(default_factory=dict)

    # --- CIT ops (unicast targets of fingerprint-routed I/O) ---------------
    def cit_lookup(self, fp: Fingerprint) -> CITEntry | None:
        return self.cit.get(fp)

    def cit_insert(self, fp: Fingerprint, size: int, now: int) -> CITEntry:
        if fp in self.cit:
            raise KeyError(f"CIT entry exists for {fp}")
        e = CITEntry(refcount=0, flag=INVALID, size=size, invalid_since=now)
        self.cit[fp] = e
        return e

    def cit_set_flag(self, fp: Fingerprint, flag: int, now: int) -> None:
        e = self.cit[fp]
        if e.flag != flag:
            e.flag = flag
            e.invalid_since = now if flag == INVALID else None

    def cit_addref(self, fp: Fingerprint, delta: int = 1) -> int:
        e = self.cit[fp]
        e.refcount += delta
        if e.refcount < 0:
            raise AssertionError(f"negative refcount for {fp}")
        return e.refcount

    def cit_remove(self, fp: Fingerprint) -> None:
        del self.cit[fp]

    # --- batched CIT ops (one unicast carries many chunk ops) ---------------
    def cit_lookup_many(self, fps: list[Fingerprint]) -> list[CITEntry | None]:
        """Batched lookup — the payload of one batched unicast message."""
        cit = self.cit
        return [cit.get(fp) for fp in fps]

    def cit_insert_many(
        self, items: list[tuple[Fingerprint, int]], now: int
    ) -> list[CITEntry]:
        return [self.cit_insert(fp, size, now) for fp, size in items]

    def cit_addref_many(self, fps: list[Fingerprint], delta: int = 1) -> list[int]:
        return [self.cit_addref(fp, delta) for fp in fps]

    # --- OMAP ops (object-name-routed I/O) ----------------------------------
    def omap_put(self, entry: OMAPEntry) -> None:
        self.omap[entry.name] = entry

    def omap_get(self, name: str) -> OMAPEntry | None:
        return self.omap.get(name)

    def omap_delete(self, name: str) -> OMAPEntry | None:
        return self.omap.pop(name, None)

    # --- introspection -------------------------------------------------------
    def stored_bytes(self) -> int:
        return sum(e.size for e in self.cit.values())

    def valid_bytes(self) -> int:
        return sum(e.size for e in self.cit.values() if e.is_valid())

    def invalid_fps(self) -> list[Fingerprint]:
        return [fp for fp, e in self.cit.items() if e.flag == INVALID]
