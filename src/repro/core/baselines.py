"""Baselines the paper compares against.

* CentralDedupCluster — one deduplication metadata server: every fingerprint
  lookup and every chunking/fingerprinting operation funnels through it
  (paper Fig 4b/5a baseline). The central op counter is the contention model
  used by benchmarks/fig5a.
* DiskLocalDedupCluster — per-node (per-disk/BtrFS-style) dedup only: no
  cluster-wide duplicate detection (paper Table 2 baseline). Objects land by
  name hash; duplicates on different nodes are NOT found.
* NoDedupCluster — baseline storage system, straight-through writes
  (paper Fig 4a "Baseline Ceph").

All wire traffic goes through the same ``Transport`` as DedupCluster, so
``stats.net_bytes``/``stats.control_msgs`` are transport views here too.
Central-server *internal* work (CIT lookups against its own tables) is
deliberately NOT network traffic — it is the serialized bottleneck the
``central_ops`` counter models for fig5a.

The baselines model the *happy path* only: they use the default reliable
delivery policy and have no rollback/accounting for lost, delayed,
duplicated, or reordered messages. The message-failure surface
(drop/delay/partition/duplicate/reorder/ack_loss/chaos) and the
at-least-once retry machinery are DedupCluster features; constructing a
baseline over a non-reliable transport raises
``UnsupportedTransportPolicy`` instead of silently producing wrong stats
(every write path re-checks, so a policy swapped in after construction is
caught too).
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.chunking import ChunkingSpec, chunk_object
from repro.core.cluster import ClusterStats, ReadError, WriteError
from repro.core.dmshard import OMAPEntry
from repro.core.fingerprint import Fingerprint, name_fp, object_fp, sha256_fp
from repro.core.messages import ChunkOp, ChunkOpBatch, ChunkRead, OmapPut, RawPut
from repro.core.node import StorageNode
from repro.core.placement import ClusterMap, place
from repro.core.transport import Transport

__all__ = [
    "CentralDedupCluster",
    "DiskLocalDedupCluster",
    "NoDedupCluster",
    "ReadError",
    "UnsupportedTransportPolicy",
    "WriteError",
]


class UnsupportedTransportPolicy(RuntimeError):
    """A baseline was given a non-reliable delivery policy. Baselines model
    the happy path only — running them over a lossy transport would not
    fail loudly, it would quietly produce WRONG stats (no rollback, no
    retries, no idempotent receive paths). Use DedupCluster for any
    fault-injection study."""

    def __init__(self, cluster_kind: str, policy) -> None:
        kind = getattr(policy, "kind", None) or getattr(policy, "__name__", repr(policy))
        super().__init__(
            f"{cluster_kind} models reliable delivery only; delivery policy "
            f"{kind!r} is unsupported (drop/delay/partition/duplicate/reorder/"
            f"ack_loss/chaos and custom policies are DedupCluster features)"
        )


def _require_reliable(cluster) -> None:
    """Reject any policy not tagged as the built-in ``reliable()`` — a
    custom callable cannot be proven lossless, so it is rejected too."""
    policy = cluster.transport.policy
    if getattr(policy, "kind", None) != "reliable" or getattr(policy, "lossy", True):
        raise UnsupportedTransportPolicy(type(cluster).__name__, policy)
    if cluster.transport.retry_budget:
        raise UnsupportedTransportPolicy(type(cluster).__name__, policy)


def _init_transport_stats(cluster) -> None:
    """Shared lazy wiring for the baseline dataclasses: a Transport over the
    live nodes dict and the legacy stats facade on top of it. Rejects
    non-reliable transports up front — and the write/read paths re-check,
    catching a lossy policy swapped in after construction."""
    if cluster.transport is None:
        cluster.transport = Transport(handlers=cluster.nodes)
    _require_reliable(cluster)
    if cluster.stats is None:
        cluster.stats = ClusterStats(cluster.transport, cluster.nodes)


@dataclass
class CentralDedupCluster:
    """All dedup metadata + chunking/fingerprinting on ONE server."""

    cmap: ClusterMap
    chunking: ChunkingSpec = field(default_factory=ChunkingSpec)
    nodes: dict[str, StorageNode] = field(default_factory=dict)
    transport: Transport | None = None
    stats: ClusterStats | None = None
    now: int = 0
    # central metadata structures (the bottleneck)
    central_cit: dict[Fingerprint, tuple[int, str]] = field(default_factory=dict)  # fp -> (refcount, node)
    central_omap: dict[str, OMAPEntry] = field(default_factory=dict)
    central_ops: int = 0          # serialized ops through the central server
    central_cpu_bytes: int = 0    # bytes chunked+fingerprinted centrally

    def __post_init__(self) -> None:
        _init_transport_stats(self)

    @classmethod
    def create(cls, n_nodes: int, chunking: ChunkingSpec | None = None) -> "CentralDedupCluster":
        ids = tuple(f"oss{i}" for i in range(n_nodes))
        c = cls(cmap=ClusterMap(1, ids), chunking=(chunking or ChunkingSpec()).normalized())
        for nid in ids:
            c.nodes[nid] = StorageNode(nid)
        return c

    def write_object(self, name: str, data: bytes) -> Fingerprint:
        _require_reliable(self)
        self.stats.logical_bytes_written += len(data)
        # client -> central server (everything funnels through it)
        self.transport.client_transfer("central", len(data))
        self.central_cpu_bytes += len(data)
        chunks = chunk_object(data, self.chunking)
        fps = [sha256_fp(c) for c in chunks]
        for fp, chunk in zip(fps, chunks):
            self.central_ops += 1               # serialized CIT lookup
            hit = self.central_cit.get(fp)
            if hit is not None:
                rc, nid = hit
                self.central_cit[fp] = (rc + 1, nid)
                self.nodes[nid].stats.dedup_hits += 1
                continue
            nid = place(fp, self.cmap, 1)[0]
            # central -> storage node: raw data push, no CIT transaction
            self.transport.send("central", nid, RawPut(fp, chunk), self.now)
            self.central_cit[fp] = (1, nid)
        self.central_ops += 1                   # OMAP write
        self.central_omap[name] = OMAPEntry(name, object_fp(fps), fps, len(data))
        self.stats.writes_ok += 1
        return self.central_omap[name].object_fp

    def read_object(self, name: str) -> bytes:
        _require_reliable(self)
        self.central_ops += 1
        e = self.central_omap.get(name)
        if e is None:
            raise ReadError(name)
        out = []
        for fp in e.chunk_fps:
            self.central_ops += 1
            rc_nid = self.central_cit.get(fp)
            if rc_nid is None:
                raise ReadError(f"central CIT lost {fp}")
            out.append(self.transport.send("central", rc_nid[1], ChunkRead(fp), self.now))
        self.stats.reads_ok += 1
        return b"".join(out)

    def unique_bytes_stored(self) -> int:
        return sum(n.stored_bytes() for n in self.nodes.values())

    def space_savings(self) -> float:
        logical = self.stats.logical_bytes_written
        return 1.0 - self.unique_bytes_stored() / logical if logical else 0.0


@dataclass
class DiskLocalDedupCluster:
    """Per-node dedup only (paper Table 2 'Disk-based Dedup Approach')."""

    cmap: ClusterMap
    chunking: ChunkingSpec = field(default_factory=ChunkingSpec)
    nodes: dict[str, StorageNode] = field(default_factory=dict)
    transport: Transport | None = None
    stats: ClusterStats | None = None
    now: int = 0

    def __post_init__(self) -> None:
        _init_transport_stats(self)

    @classmethod
    def create(cls, n_nodes: int, chunking: ChunkingSpec | None = None) -> "DiskLocalDedupCluster":
        ids = tuple(f"oss{i}" for i in range(n_nodes))
        c = cls(cmap=ClusterMap(1, ids), chunking=(chunking or ChunkingSpec()).normalized())
        for nid in ids:
            c.nodes[nid] = StorageNode(nid)
        return c

    def write_object(self, name: str, data: bytes) -> Fingerprint:
        _require_reliable(self)
        self.stats.logical_bytes_written += len(data)
        nid = place(name_fp(name), self.cmap, 1)[0]   # object placed by name
        node = self.nodes[nid]
        self.transport.client_transfer(nid, len(data))
        chunks = chunk_object(data, self.chunking)
        fps = [sha256_fp(c) for c in chunks]
        # local dedup transaction: ops originate and apply on the same node
        ops = tuple(ChunkOp(fp, chunk, origin=nid) for fp, chunk in zip(fps, chunks))
        self.transport.send(nid, nid, ChunkOpBatch(ops, txn=0), self.now)
        # per-disk dedup has no async window: the flag update is part of the
        # local write, so flips drain synchronously.
        node.cm.drain(node.shard, self.now + node.cm.async_delay)
        self.transport.send(
            nid, nid, OmapPut(OMAPEntry(name, object_fp(fps), fps, len(data))), self.now
        )
        self.stats.writes_ok += 1
        return object_fp(fps)

    def read_object(self, name: str) -> bytes:
        _require_reliable(self)
        nid = place(name_fp(name), self.cmap, 1)[0]
        node = self.nodes[nid]
        e = node.shard.omap_get(name)
        if e is None:
            raise ReadError(name)
        data = b"".join(node.chunk_store[fp] for fp in e.chunk_fps)
        self.stats.reads_ok += 1
        return data

    def unique_bytes_stored(self) -> int:
        return sum(n.stored_bytes() for n in self.nodes.values())

    def space_savings(self) -> float:
        logical = self.stats.logical_bytes_written
        return 1.0 - self.unique_bytes_stored() / logical if logical else 0.0


@dataclass
class NoDedupCluster:
    """Baseline storage system without any deduplication (Fig 4a 'Baseline')."""

    cmap: ClusterMap
    nodes: dict[str, StorageNode] = field(default_factory=dict)
    transport: Transport | None = None
    stats: ClusterStats | None = None
    objects: dict[str, str] = field(default_factory=dict)  # name -> node

    def __post_init__(self) -> None:
        _init_transport_stats(self)

    @classmethod
    def create(cls, n_nodes: int) -> "NoDedupCluster":
        ids = tuple(f"oss{i}" for i in range(n_nodes))
        c = cls(cmap=ClusterMap(1, ids))
        for nid in ids:
            c.nodes[nid] = StorageNode(nid)
        return c

    def write_object(self, name: str, data: bytes) -> None:
        _require_reliable(self)
        self.stats.logical_bytes_written += len(data)
        nid = place(name_fp(name), self.cmap, 1)[0]
        # whole object travels client -> node as one raw store
        self.transport.send("client", nid, RawPut(name_fp(name), data), 0)
        self.stats.writes_ok += 1

    def read_object(self, name: str) -> bytes:
        _require_reliable(self)
        nid = place(name_fp(name), self.cmap, 1)[0]
        data = self.nodes[nid].chunk_store.get(name_fp(name))
        if data is None:
            raise ReadError(name)
        self.stats.reads_ok += 1
        return data

    def unique_bytes_stored(self) -> int:
        return sum(n.stored_bytes() for n in self.nodes.values())
