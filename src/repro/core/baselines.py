"""Baselines the paper compares against.

* CentralDedupCluster — one deduplication metadata server: every fingerprint
  lookup and every chunking/fingerprinting operation funnels through it
  (paper Fig 4b/5a baseline). The central op counter is the contention model
  used by benchmarks/fig5a.
* DiskLocalDedupCluster — per-node (per-disk/BtrFS-style) dedup only: no
  cluster-wide duplicate detection (paper Table 2 baseline). Objects land by
  name hash; duplicates on different nodes are NOT found.
* NoDedupCluster — baseline storage system, straight-through writes
  (paper Fig 4a "Baseline Ceph").
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.chunking import ChunkingSpec, chunk_object
from repro.core.cluster import ClusterStats, ReadError, WriteError
from repro.core.dmshard import OMAPEntry
from repro.core.fingerprint import Fingerprint, name_fp, object_fp, sha256_fp
from repro.core.node import StorageNode
from repro.core.placement import ClusterMap, place


@dataclass
class CentralDedupCluster:
    """All dedup metadata + chunking/fingerprinting on ONE server."""

    cmap: ClusterMap
    chunking: ChunkingSpec = field(default_factory=ChunkingSpec)
    nodes: dict[str, StorageNode] = field(default_factory=dict)
    stats: ClusterStats = field(default_factory=ClusterStats)
    now: int = 0
    # central metadata structures (the bottleneck)
    central_cit: dict[Fingerprint, tuple[int, str]] = field(default_factory=dict)  # fp -> (refcount, node)
    central_omap: dict[str, OMAPEntry] = field(default_factory=dict)
    central_ops: int = 0          # serialized ops through the central server
    central_cpu_bytes: int = 0    # bytes chunked+fingerprinted centrally

    @classmethod
    def create(cls, n_nodes: int, chunking: ChunkingSpec | None = None) -> "CentralDedupCluster":
        ids = tuple(f"oss{i}" for i in range(n_nodes))
        c = cls(cmap=ClusterMap(1, ids), chunking=(chunking or ChunkingSpec()).normalized())
        for nid in ids:
            c.nodes[nid] = StorageNode(nid)
        return c

    def write_object(self, name: str, data: bytes) -> Fingerprint:
        self.stats.logical_bytes_written += len(data)
        # client -> central server (everything funnels through it)
        self.stats.net_bytes += len(data)
        self.central_cpu_bytes += len(data)
        chunks = chunk_object(data, self.chunking)
        fps = [sha256_fp(c) for c in chunks]
        for fp, chunk in zip(fps, chunks):
            self.central_ops += 1               # serialized CIT lookup
            self.stats.control_msgs += 1
            hit = self.central_cit.get(fp)
            if hit is not None:
                rc, nid = hit
                self.central_cit[fp] = (rc + 1, nid)
                self.nodes[nid].stats.dedup_hits += 1
                continue
            nid = place(fp, self.cmap, 1)[0]
            node = self.nodes[nid]
            node.chunk_store[fp] = chunk
            node.stats.disk_bytes_written += len(chunk)
            node.stats.chunk_writes += 1
            self.stats.net_bytes += len(chunk)  # central -> storage node
            self.central_cit[fp] = (1, nid)
        self.central_ops += 1                   # OMAP write
        self.central_omap[name] = OMAPEntry(name, object_fp(fps), fps, len(data))
        self.stats.writes_ok += 1
        return self.central_omap[name].object_fp

    def read_object(self, name: str) -> bytes:
        self.central_ops += 1
        e = self.central_omap.get(name)
        if e is None:
            raise ReadError(name)
        out = []
        for fp in e.chunk_fps:
            self.central_ops += 1
            rc_nid = self.central_cit.get(fp)
            if rc_nid is None:
                raise ReadError(f"central CIT lost {fp}")
            out.append(self.nodes[rc_nid[1]].chunk_store[fp])
            self.stats.net_bytes += len(out[-1])
        self.stats.reads_ok += 1
        return b"".join(out)

    def unique_bytes_stored(self) -> int:
        return sum(n.stored_bytes() for n in self.nodes.values())

    def space_savings(self) -> float:
        logical = self.stats.logical_bytes_written
        return 1.0 - self.unique_bytes_stored() / logical if logical else 0.0


@dataclass
class DiskLocalDedupCluster:
    """Per-node dedup only (paper Table 2 'Disk-based Dedup Approach')."""

    cmap: ClusterMap
    chunking: ChunkingSpec = field(default_factory=ChunkingSpec)
    nodes: dict[str, StorageNode] = field(default_factory=dict)
    stats: ClusterStats = field(default_factory=ClusterStats)
    now: int = 0

    @classmethod
    def create(cls, n_nodes: int, chunking: ChunkingSpec | None = None) -> "DiskLocalDedupCluster":
        ids = tuple(f"oss{i}" for i in range(n_nodes))
        c = cls(cmap=ClusterMap(1, ids), chunking=(chunking or ChunkingSpec()).normalized())
        for nid in ids:
            c.nodes[nid] = StorageNode(nid)
        return c

    def write_object(self, name: str, data: bytes) -> Fingerprint:
        self.stats.logical_bytes_written += len(data)
        nid = place(name_fp(name), self.cmap, 1)[0]   # object placed by name
        node = self.nodes[nid]
        self.stats.net_bytes += len(data)
        chunks = chunk_object(data, self.chunking)
        fps = [sha256_fp(c) for c in chunks]
        for fp, chunk in zip(fps, chunks):
            node.stats.cit_lookups += 1
            if node.shard.cit_lookup(fp) is not None:   # local-only dedup
                node.shard.cit_addref(fp)
                node.stats.dedup_hits += 1
                continue
            node.shard.cit_insert(fp, len(chunk), self.now)
            node.shard.cit_addref(fp)
            node.shard.cit_set_flag(fp, 1, self.now)
            node.chunk_store[fp] = chunk
            node.stats.disk_bytes_written += len(chunk)
            node.stats.chunk_writes += 1
        node.shard.omap_put(OMAPEntry(name, object_fp(fps), fps, len(data)))
        self.stats.writes_ok += 1
        return object_fp(fps)

    def read_object(self, name: str) -> bytes:
        nid = place(name_fp(name), self.cmap, 1)[0]
        node = self.nodes[nid]
        e = node.shard.omap_get(name)
        if e is None:
            raise ReadError(name)
        data = b"".join(node.chunk_store[fp] for fp in e.chunk_fps)
        self.stats.reads_ok += 1
        return data

    def unique_bytes_stored(self) -> int:
        return sum(n.stored_bytes() for n in self.nodes.values())

    def space_savings(self) -> float:
        logical = self.stats.logical_bytes_written
        return 1.0 - self.unique_bytes_stored() / logical if logical else 0.0


@dataclass
class NoDedupCluster:
    """Baseline storage system without any deduplication (Fig 4a 'Baseline')."""

    cmap: ClusterMap
    nodes: dict[str, StorageNode] = field(default_factory=dict)
    stats: ClusterStats = field(default_factory=ClusterStats)
    objects: dict[str, str] = field(default_factory=dict)  # name -> node

    @classmethod
    def create(cls, n_nodes: int) -> "NoDedupCluster":
        ids = tuple(f"oss{i}" for i in range(n_nodes))
        c = cls(cmap=ClusterMap(1, ids))
        for nid in ids:
            c.nodes[nid] = StorageNode(nid)
        return c

    def write_object(self, name: str, data: bytes) -> None:
        self.stats.logical_bytes_written += len(data)
        nid = place(name_fp(name), self.cmap, 1)[0]
        node = self.nodes[nid]
        self.stats.net_bytes += len(data)
        node.chunk_store[name_fp(name)] = data
        node.stats.disk_bytes_written += len(data)
        self.stats.writes_ok += 1

    def read_object(self, name: str) -> bytes:
        nid = place(name_fp(name), self.cmap, 1)[0]
        data = self.nodes[nid].chunk_store.get(name_fp(name))
        if data is None:
            raise ReadError(name)
        self.stats.reads_ok += 1
        return data

    def unique_bytes_stored(self) -> int:
        return sum(n.stored_bytes() for n in self.nodes.values())
