"""Typed wire messages between shared-nothing storage nodes.

Every cluster interaction — chunk writes, OMAP operations, refcount
releases, reads, rebalance moves — is a message sent through
``repro.core.transport.Transport``. Each message computes its own wire
footprint so payload + control accounting lives in one place instead of
being hand-maintained at every call site:

    wire_bytes(dst, response) = CONTROL_MSG_BYTES            (header/ack)
                              + payload_bytes(dst, response) (request data)
                              + response_payload_bytes(response)

Accounting conventions (all preserved from the pre-transport model so the
benchmark trajectories stay comparable):

* chunk payload is free when the op *originates* on the destination — the
  primary already holds those bytes (``ChunkOp.origin``);
* with ``fp_first`` (beyond-paper probe-before-send), chunk bytes only
  travel for ops that were not dedup hits, which is knowable only after
  delivery — hence ``payload_bytes`` takes the response;
* OMAP commit records are control-only; *migrating* a stored OMAP entry
  during rebalance ships a CONTROL_MSG_BYTES-sized record (``migrate=True``);
* ``lookups()`` counts the CIT fingerprint lookups a message carries —
  the unicast-vs-broadcast currency of the paper's Fig 2 argument.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.core.dmshard import CITEntry, OMAPEntry
from repro.core.fingerprint import Fingerprint

CONTROL_MSG_BYTES = 64  # modeled size of a lookup/refcount message header
ACK_MSG_BYTES = 64      # modeled size of the per-delivery ack on the reverse edge

# Recovery digest wire model (docs/recovery.md): a summary digest costs a
# fixed record per placement group, detail listings cost a record per entry.
# Digest-diff recovery trades these small records against shipping (or
# omnisciently scanning) whole CIT/OMAP tables — the scalable-reconciliation
# argument of the disaster-recovery literature.
DIGEST_GROUP_BYTES = 16   # per-group summary record: (count, xor-of-hashes)
DIGEST_ENTRY_BYTES = 56   # per-fp detail record: fp + (has_bytes, refcount, flag, size, mtime)
RECIPE_REF_BYTES = 40     # per (chunk_fp, count) recipe-reference pair (audit)
OMAP_DIGEST_ENTRY_BYTES = 64  # per-name detail record: name hash + object fp + version + tombstone marker
TOMBSTONE_RECORD_BYTES = 24   # per aged-tombstone candidate: name hash + version + age
PRESENCE_FP_BYTES = 32        # per fingerprint in a presence-cache invalidation fan-out


class Message:
    """Base for all wire messages. Subclasses are frozen dataclasses."""

    TYPE: str = "message"

    def payload_bytes(self, dst: str, response=None) -> int:
        """Request payload crossing the wire toward ``dst``."""
        return 0

    def response_payload_bytes(self, response) -> int:
        """Response payload crossing the wire back to the sender."""
        return 0

    def lookups(self) -> int:
        """CIT fingerprint lookups carried by this message."""
        return 0

    def wire_bytes(self, dst: str, response=None) -> int:
        return (
            CONTROL_MSG_BYTES
            + self.payload_bytes(dst, response)
            + self.response_payload_bytes(response)
        )


@dataclass(frozen=True)
class ChunkOp:
    """One fingerprint-routed chunk operation inside a ChunkOpBatch.

    ``data is None`` is a *ref-only* op: the sender knows the bytes already
    exist on the destination (intra-batch duplicate or reference write) and
    asks only for a refcount increment — nothing but the fingerprint travels.
    ``origin`` is the OSS that produced the op (the object's primary): ops
    delivered to their own origin cost no network payload.

    ``presence=True`` marks a ref-only op asserted from a client presence
    cache: the sender holds positive (possibly stale) evidence the chunk
    already exists cluster-wide, so the op is a blind incref *record*
    rather than a fingerprint *query* — it is excluded from ``lookups()``
    (the probe-elision win). The receiver still validates locally and
    answers 'miss' when the evidence was stale; the sender then falls back
    to shipping the bytes, so stale presence degrades, never dangles.
    """

    fp: Fingerprint
    data: bytes | None = None
    origin: str = "client"
    presence: bool = False


@dataclass(frozen=True)
class ChunkOpBatch(Message):
    """One unicast carrying many chunk ops — possibly for many objects
    (cross-object coalescing: ``write_objects`` emits one of these per
    target node for the whole batch). Ops apply in order; the response is
    the per-op outcome list ('dedup_hit'|'repaired'|'restored'|'stored'|
    'miss')."""

    TYPE = "chunk_op_batch"
    ops: tuple[ChunkOp, ...] = ()
    txn: int = 0
    fp_first: bool = False  # beyond-paper: 64B probe first, bytes on miss only

    def payload_bytes(self, dst: str, response=None) -> int:
        total = 0
        outcomes = response if response is not None else [None] * len(self.ops)
        for op, outcome in zip(self.ops, outcomes):
            if op.data is None or op.origin == dst:
                continue
            if self.fp_first and outcome == "dedup_hit":
                continue  # probe hit: bytes never traveled
            total += len(op.data)
        return total

    def lookups(self) -> int:
        return sum(1 for op in self.ops if not op.presence)


@dataclass(frozen=True)
class OmapPut(Message):
    """Object-name-routed OMAP record write. A transaction commit record is
    modeled as control-only; ``migrate=True`` (rebalance) ships the stored
    entry as a CONTROL_MSG_BYTES record, as in the pre-transport model."""

    TYPE = "omap_put"
    entry: OMAPEntry = None  # type: ignore[assignment]
    migrate: bool = False

    def payload_bytes(self, dst: str, response=None) -> int:
        return CONTROL_MSG_BYTES if self.migrate else 0


@dataclass(frozen=True)
class OmapGet(Message):
    TYPE = "omap_get"
    name: str = ""


@dataclass(frozen=True)
class OmapDelete(Message):
    """Object-name-routed delete: commits a versioned TOMBSTONE record in
    place of the live entry (never a bare removal — a replica that missed
    the delete while unreachable would be indistinguishable from one that
    missed the put, and OMAP repair would resurrect the name). ``version``
    is the deleting transaction's cluster-monotonic id, the same authority
    currency as ``OMAPEntry.version``: a tombstone beats any stale live
    replica and a newer recreate beats the tombstone, by version, never by
    placement order. Control-only on the wire; the response is the live
    entry the tombstone replaced (cached in the seen-window so a
    conditional cancel can restore it)."""

    TYPE = "omap_delete"
    name: str = ""
    version: int = 0


@dataclass(frozen=True)
class TombstoneReap(Message):
    """GC-horizon reap (coordinator -> holder): physically remove the
    tombstone record for ``name`` iff the holder still has a tombstone at
    exactly ``version`` — a newer write or newer delete is left untouched.
    Sent only once the recovery round has proof the tombstone is FULLY
    ACKED (every live placement target listed it as aged past the GC
    horizon), so no stale live replica can remain that the tombstone still
    needs to beat. Control-only on the request wire; a successful reap's
    response carries the tombstone's retained chunk fingerprints (the
    deleted recipe, ``PRESENCE_FP_BYTES`` each) so the coordinator can fan
    out a last-chance ``PresenceInvalidate``."""

    TYPE = "tombstone_reap"
    name: str = ""
    version: int = 0

    def response_payload_bytes(self, response: object) -> int:
        if isinstance(response, tuple) and len(response) == 2:
            return PRESENCE_FP_BYTES * len(response[1])
        return 0


@dataclass(frozen=True)
class DecrefBatch(Message):
    """Batched refcount release (delete / transaction rollback): one unicast
    releasing many references on one node. A fingerprint may appear more
    than once (one decrement each). ``audit=True`` marks corrections emitted
    by the cluster-wide refcount audit: references the audit *proved*
    unreferenced by any OMAP recipe skip the GC aging wait (the audit's
    recipe walk IS the cross-match evidence aging normally buys)."""

    TYPE = "decref_batch"
    fps: tuple[Fingerprint, ...] = ()
    audit: bool = False


@dataclass(frozen=True)
class RefOnlyWrite(Message):
    """Reference-only write: increment refcounts for ``fps`` without moving
    data (checkpointer device-fp fast path). Each fp is a CIT lookup; the
    response is a per-fp 'ok'|'miss' tuple ('miss' = entry absent or
    invalid with no local bytes — the caller falls back to a full write)."""

    TYPE = "ref_only_write"
    fps: tuple[Fingerprint, ...] = ()

    def lookups(self) -> int:
        return len(self.fps)


@dataclass(frozen=True)
class ChunkRead(Message):
    """Fingerprint-routed chunk fetch; the chunk bytes come back in the
    response."""

    TYPE = "chunk_read"
    fp: Fingerprint = None  # type: ignore[assignment]

    def response_payload_bytes(self, response) -> int:
        return len(response) if isinstance(response, (bytes, bytearray)) else 0


@dataclass(frozen=True)
class ChunkReadBatch(Message):
    """One unicast fetching many chunks from one node — possibly for many
    objects (the restore-side twin of ``ChunkOpBatch``'s cross-object
    coalescing: ``read_objects`` emits one of these per target node per
    wave, after eliding intra-batch duplicate fingerprints through its
    first-reader cache). Control-only on the request wire, like
    ``ChunkRead``; the returned chunk bytes are charged as response
    payload via ``ChunkReadBatchReply.reply_bytes`` so payload parity
    with the serial shape holds exactly. Reads are content-addressed
    fetches, not CIT queries, so ``lookups()`` stays 0 — same as the
    serial read path."""

    TYPE = "chunk_read_batch"
    fps: tuple[Fingerprint, ...] = ()

    def response_payload_bytes(self, response) -> int:
        if isinstance(response, ChunkReadBatchReply):
            return response.reply_bytes()
        return 0


@dataclass(frozen=True)
class ChunkReadBatchReply(Message):
    """Per-fp outcome of a ``ChunkReadBatch``, parallel to the request's
    ``fps``: the chunk bytes on a hit, ``None`` on a miss (bytes absent —
    or corrupt — on this replica). Reporting misses per fp instead of
    raising lets one degraded chunk fail alone: the sender re-requests
    ONLY the misses from the next untried replica in a follow-up batch
    (``ClusterStats.read_fallback_rounds``) while the hits are kept.
    Wire cost is the hit bytes; misses ride the control header for free."""

    TYPE = "chunk_read_batch_reply"
    chunks: tuple = ()  # tuple[bytes | None, ...] parallel to request fps

    def reply_bytes(self) -> int:
        return sum(len(b) for b in self.chunks if b is not None)


@dataclass(frozen=True)
class MigrateChunk(Message):
    """Rebalance/scrub move: chunk bytes (``data``; None when the
    destination already holds them) plus the CIT entry snapshot that travels
    with its chunk — the paper's 'metadata moves with content' property."""

    TYPE = "migrate_chunk"
    fp: Fingerprint = None  # type: ignore[assignment]
    data: bytes | None = None
    cit: CITEntry | None = None

    def payload_bytes(self, dst: str, response=None) -> int:
        return len(self.data) if self.data is not None else 0


@dataclass(frozen=True)
class DigestRequest(Message):
    """Recovery digest probe (coordinator -> node). The node summarizes its
    OWN holdings — it never answers for anyone else — and the reply rides
    the ack like every response.

    ``kind``:
      * ``"chunks"``  — per-placement-group (count, xor-hash) summary of the
        node's chunk/CIT holdings; with ``groups`` set, a per-fp detail
        listing for exactly those groups; with ``detail_all=True``, details
        for everything (the audit's actual-refcount source).
      * ``"omap"``    — the same two-level digest over OMAP entries, grouped
        by object-name placement.
      * ``"recipes"`` — aggregated chunk-reference counts from the recipes
        this node *owns* (it is the first LIVE name-hash target given
        ``live``) — the audit's expected-refcount source; each logical
        object is counted by exactly one owner.

    The cluster map travels with the request (versioned, tiny — modeled as
    control-only, like an OSDMap epoch share) so the node groups by the
    placement the coordinator is reconciling against.

    Incremental (epoch-scoped) digests: with ``since_epoch`` set, the node
    summarizes ONLY the placement groups its dirty-epoch tracker marked at
    or after that epoch (write/delete/rebalance traffic bumps a group's
    dirty epoch; a cluster-map change marks everything dirty) and reports
    how many clean groups it skipped — the always-on repair loop's way of
    re-digesting just the slice that changed since its last completed
    round. ``summary_only`` asks for exact (count, xor) summaries of the
    named ``groups`` with no per-entry detail: the coordinator's second
    probe to members that reported a group clean when some peer reported
    it dirty (an explicit empty summary is then distinguishable from
    "not probed")."""

    TYPE = "digest_request"
    kind: str = "chunks"
    cmap: object = None           # ClusterMap (placement the digest is keyed by)
    groups: tuple = ()            # () = summary; else detail for these groups
    detail_all: bool = False      # detail for every group (audit)
    live: tuple[str, ...] = ()    # live set for recipe ownership (kind="recipes")
    since_epoch: int | None = None  # incremental: summarize groups dirty since
    summary_only: bool = False    # with ``groups``: summaries, no detail

    def response_payload_bytes(self, response) -> int:
        if isinstance(response, DigestReply):
            return response.reply_bytes()
        return 0


@dataclass(frozen=True)
class DigestReply(Message):
    """A node's digest of its own holdings (the response riding a
    ``DigestRequest`` ack). ``groups`` maps placement-group key ->
    ``(count, xor_hash)``; ``entries`` carries detail records:

      * chunks detail: fp -> (has_bytes, has_cit, refcount, flag, size, mtime)
      * omap detail:   name -> (object_fp, version, deleted, deleted_at)
      * recipes:       fp -> reference count from owned recipes

    ``epoch`` is the node's serve time — the epoch the digest describes.
    With an incremental request (``since_epoch``), ``skipped_groups``
    counts the clean placement groups the node did NOT re-digest, and an
    omap summary reply additionally lists the node's aged tombstone
    candidates (``tombstones``: name -> (version, deleted_at), only those
    past the GC horizon) so the coordinator can reap fully-acked ones —
    O(aged tombstones) wire, never a table walk.

    Wire cost is per record (see the DIGEST_*/RECIPE_*/TOMBSTONE_*
    constants) — the whole point of digest-based reconciliation: summaries
    are O(groups), details are fetched only for groups that disagree."""

    TYPE = "digest_reply"
    kind: str = "chunks"
    groups: dict = None           # type: ignore[assignment]
    entries: dict = None          # type: ignore[assignment]
    epoch: int = 0                # node's serve time (the digest's epoch)
    skipped_groups: int = 0       # clean groups an incremental probe skipped
    tombstones: dict | None = None  # name -> (version, deleted_at), aged only

    def reply_bytes(self) -> int:
        total = DIGEST_GROUP_BYTES * len(self.groups or ())
        total += TOMBSTONE_RECORD_BYTES * len(self.tombstones or ())
        n = len(self.entries or ())
        if self.kind == "recipes":
            total += RECIPE_REF_BYTES * n
        elif self.kind == "omap":
            total += OMAP_DIGEST_ENTRY_BYTES * n
        else:
            total += DIGEST_ENTRY_BYTES * n
        return total


@dataclass(frozen=True)
class RepairChunk(Message):
    """Digest-diff repair move (holder -> target): chunk bytes (``data``;
    None for a metadata-only repair) and/or the CIT entry snapshot a target
    is missing. Unlike the rebalance ``MigrateChunk`` the snapshot here is
    reconstructed from wire-learned digest details, not read from a foreign
    shard. Receiver-side it is adopt-if-missing (idempotent) and rides the
    seen-window like every mutating message; the response reports what was
    actually adopted ('stored'|'present', 'cit_stored'|'cit_present'|'')."""

    TYPE = "repair_chunk"
    fp: Fingerprint = None  # type: ignore[assignment]
    data: bytes | None = None
    cit: CITEntry | None = None

    def payload_bytes(self, dst: str, response=None) -> int:
        return len(self.data) if self.data is not None else 0


@dataclass(frozen=True)
class RefAudit(Message):
    """Refcount-audit correction (coordinator -> CIT owner): for each
    ``(fp, expected_refcount)`` item the node raises a refcount that is
    BELOW what the cluster's recipes reference (a replica that missed
    increfs while unreachable) and repairs a stuck-INVALID flag when the
    recipes prove the chunk live and the bytes are present (the lost
    async-flip case). Excess references travel separately as audit-tagged
    ``DecrefBatch`` messages. Control-only on the wire; ``lookups()``
    counts the CIT probes carried."""

    TYPE = "ref_audit"
    items: tuple = ()             # ((fp, expected_refcount), ...)

    def lookups(self) -> int:
        return len(self.items)


@dataclass(frozen=True)
class TxnCancel(Message):
    """Conditional compensation for the at-least-once ambiguity window.

    When a sender exhausts its retry budget with ``maybe_applied`` — some
    attempt reached the receiver but no ack came back — it cannot tell
    "ack lost, op applied" from "op lost". ``TxnCancel`` resolves it AT the
    receiver: if ``ref_msg_id`` is in the receiver's seen-window the
    original message applied, so its effects are compensated (refcounts
    released per the cached per-op outcomes; the OMAP entry removed when
    ``omap_name`` is set). If it is NOT seen, the id is poisoned so a copy
    still in flight is discarded on arrival instead of resurrecting the
    cancelled transaction. Control-only on the wire.

    ``undelete=True`` cancels an unconfirmed ``OmapDelete`` instead of an
    unconfirmed commit: if the tombstone at exactly ``ref_version`` is
    still in place, the pre-delete entry (the delete's cached response)
    is restored — a newer write or newer delete is left untouched."""

    TYPE = "txn_cancel"
    ref_msg_id: int = 0
    fps: tuple[Fingerprint, ...] = ()
    omap_name: str | None = None
    undelete: bool = False
    ref_version: int = 0


@dataclass(frozen=True)
class PresenceInvalidate(Message):
    """Presence-cache invalidation fan-out (node/coordinator -> client
    session): the listed fingerprints may no longer exist cluster-wide, so
    any cached "exists" evidence for them must be dropped. Emitted on
    delete (the recipe's refs were released), on GC reclaim (the aged
    sweep physically removed chunks), and on tombstone reap (last-chance
    re-invalidation riding the reap proof). Delivery is best-effort on
    purpose: the handler is idempotent (dropping an fp twice is a no-op)
    and a LOST invalidation only leaves stale presence, which the
    receiver-side validation of presence-asserted ops already degrades to
    a fallback byte resend — correctness never rests on this message
    arriving. ``reason`` is one of 'delete'|'gc'|'reap' (stats only).
    Costs ``PRESENCE_FP_BYTES`` per fingerprint on the wire."""

    TYPE = "presence_invalidate"
    fps: tuple[Fingerprint, ...] = ()
    reason: str = "delete"

    def payload_bytes(self, dst: str, response=None) -> int:
        return PRESENCE_FP_BYTES * len(self.fps)


@dataclass(frozen=True)
class RawPut(Message):
    """Baseline-only store: raw bytes placed under a fingerprint with no
    CIT transaction (central-dedup data push, no-dedup object store)."""

    TYPE = "raw_put"
    fp: Fingerprint = None  # type: ignore[assignment]
    data: bytes = b""

    def payload_bytes(self, dst: str, response=None) -> int:
        return len(self.data)


MESSAGE_TYPES = (
    ChunkOpBatch,
    OmapPut,
    OmapGet,
    OmapDelete,
    TombstoneReap,
    DecrefBatch,
    RefOnlyWrite,
    ChunkRead,
    ChunkReadBatch,
    ChunkReadBatchReply,
    MigrateChunk,
    DigestRequest,
    DigestReply,
    RepairChunk,
    RefAudit,
    TxnCancel,
    PresenceInvalidate,
    RawPut,
)
