"""Message-driven recovery subsystem: digest-diff repair, cluster-wide
refcount audit, post-partition reconciliation.

The paper's headline claim is robustness under sudden server failure; this
module is the repair half of that claim, built so every recovery action is
a typed message on the transport (``core/messages.py``) rather than an
omniscient cluster-level scan:

* **Digest exchange** — a recovery coordinator probes each node with
  ``DigestRequest``; the node answers with per-placement-group
  ``(count, xor-hash)`` summaries of its OWN holdings (``DigestReply``).
  Only groups whose replica digests disagree are expanded into per-entry
  detail listings, so reconciliation wire cost is O(groups) plus
  O(entries of the divergent slice) — the digest-based alternative to
  shipping (or omnisciently reading) whole tables.
* **Digest-diff repair** — for every fingerprint a live placement target
  is missing, a holder ships ``RepairChunk`` (bytes and/or a CIT snapshot
  reconstructed from wire-learned detail). Source selection prefers a
  holder whose shard actually has the CIT entry; when bytes and metadata
  live on different survivors, each ships from the node that has it.
* **Cluster-wide refcount audit** — expected reference counts are
  recomputed from OMAP recipes, walked by name-hash OWNER (each logical
  object counted by exactly one live owner even though OMAP is
  replicated), and reconciled against every CIT replica: excess refs are
  released through audit-tagged ``DecrefBatch`` messages (which feed the
  GC's aging cross-match), missing refs and stuck-INVALID flags are
  corrected through ``RefAudit``. This closes, by construction, the
  at-least-once residual window where a ``TxnCancel`` is itself lost
  after an applied-but-unacked op: the leaked references are exactly the
  ones no recipe accounts for.
* **Post-partition reconciliation** — ``run()`` chains OMAP repair →
  chunk digest repair → refcount audit → GC, converging a healed
  split-brain cluster to the state a never-partitioned one would hold.

State-access discipline: the coordinator learns remote state ONLY from
digest replies that traveled (and can be lost / duplicated / reordered)
on the wire. The only direct object access is *sender-local*: reading a
holder's own chunk store / OMAP to build the message that holder sends —
the same idiom as rebalance, where a node reads its own disk to transmit.

Deletes are recovery-safe: ``OmapDelete`` commits a VERSIONED tombstone
record that is replicated, digested and repaired exactly like a live
entry — authority is the highest commit version regardless of liveness,
so a tombstone beats any stale live replica (no resurrection) and a
recreate beats a stale tombstone, including across partitions. Tombstones
past the GC horizon are reaped only on cluster-wide full-ack proof
(every live placement target lists the aged tombstone at the same
version), via ``TombstoneReap``.

Recovery is also ALWAYS-ON capable: digests carry an epoch, nodes track
per-placement-group dirty epochs, and an incremental round
(``since_epoch``) re-digests only groups mutated since the last completed
round — clean groups are skipped and counted. A second summary-only probe
wave disambiguates "skipped because clean" from "holds nothing" for
groups a peer reported. ``RepairDaemon`` packages this as a background
loop that interleaves with live writes; its refcount audit excludes
fingerprints any replica touched at or after the round's start epoch
(in-flight transactions are deferred to the next round, not misjudged).
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.dmshard import CITEntry, INVALID, VALID
from repro.core.fingerprint import Fingerprint, name_fp
from repro.core.messages import (
    DecrefBatch,
    DigestRequest,
    MigrateChunk,
    OmapPut,
    RefAudit,
    RepairChunk,
    TombstoneReap,
)
from repro.core.node import NodeDown
from repro.core.placement import place
from repro.core.transport import MessageDropped

# The recovery coordinator's transport identity. Like the external
# "client", it is not a member of any partition group — recovery runs
# post-heal by definition — but every message it triggers between NODES
# (RepairChunk, holder-sourced OmapPut) is subject to the delivery policy.
RECOVERY_SRC = "recovery"


@dataclass
class RecoveryReport:
    """What one recovery round observed and corrected."""

    digest_msgs: int = 0          # DigestRequest probes sent (summary + detail)
    groups_checked: int = 0       # placement groups compared across replicas
    groups_mismatched: int = 0    # groups whose replica digests disagreed
    omap_repaired: int = 0        # OMAP entries restored onto missing replicas
    chunks_repaired: int = 0      # chunk byte copies restored (scrub's currency)
    cit_repaired: int = 0         # CIT entry snapshots restored
    repair_bytes: int = 0         # chunk bytes shipped by RepairChunk
    refs_over: int = 0            # excess references released by the audit
    refs_under: int = 0           # missing references restored by the audit
    flags_flipped: int = 0        # stuck-INVALID flags the audit repaired
    audit_msgs: int = 0           # correction messages (DecrefBatch + RefAudit)
    audit_skipped: bool = False   # recipes unreadable from a live node -> no audit
    missing_entries: int = 0      # recipe-referenced fps with no CIT entry on a target
    unrecoverable: int = 0        # fps whose bytes survive on no holder
    gc_removed: int = 0           # chunks GC reclaimed during the round
    unreachable: int = 0          # digest probes lost (node skipped this round)
    groups_digested: int = 0      # group summaries nodes actually computed
    groups_skipped: int = 0       # clean groups incremental probes skipped
    tombstones_reaped: int = 0    # aged tombstone removals acked (full-ack reap)
    audit_deferred: int = 0       # fps excluded from the audit as in-flight

    @property
    def corrections(self) -> int:
        return self.refs_over + self.refs_under + self.flags_flipped


@dataclass
class RecoveryRound:
    """One recovery pass, split into explicit phases so callers (and
    tests) can interleave cluster events — a rebalance landing between
    digest collection and repair must not double-repair a migrated chunk:
    placement is re-resolved against the CURRENT map at every send, and
    the repair handler is adopt-if-missing either way."""

    cluster: object
    src: str = RECOVERY_SRC
    # Incremental floor: only placement groups a node marked dirty at or
    # after this epoch are re-digested (None = full round, every group).
    since_epoch: int | None = None
    # Audit concurrency gate: fingerprints whose CIT entry ANY replica
    # mutated at or after this epoch belong to transactions in flight
    # while the round runs — they are deferred, not judged (None = quiesced
    # round, judge everything).
    exclude_after: int | None = None
    report: RecoveryReport = field(default_factory=RecoveryReport)
    _chunk_digests: dict = field(default_factory=dict)   # nid -> {group: (count, xor)}
    _aged_tombstones: dict = field(default_factory=dict) # nid -> {name: (ver, at)}
    _tombstones_collected: bool = False
    # None = repair_omap has not run this round (standalone audits are the
    # caller's responsibility); False = it ran but lost probes, so OMAP
    # replicas may still be incomplete and the audit must not trust the
    # recipe walk (an unrepaired owner under-counts its objects' refs).
    _omap_repair_complete: bool | None = None

    # ------------------------------------------------------------- plumbing
    def _live(self) -> list[str]:
        return [nid for nid, n in self.cluster.nodes.items() if n.alive]

    def _ask(self, nid: str, msg: DigestRequest):
        """One digest probe; a reply lost past the retry budget skips the
        node for this round (counted) instead of failing recovery."""
        self.report.digest_msgs += 1
        try:
            return self.cluster.transport.send(self.src, nid, msg, self.cluster.now)
        except (MessageDropped, NodeDown):
            self.report.unreachable += 1
            return None

    def _send(self, src: str, dst: str, msg) -> object | None:
        try:
            return self.cluster.transport.send(src, dst, msg, self.cluster.now)
        except (MessageDropped, NodeDown):
            return None

    @staticmethod
    def _mismatched(replies: dict) -> tuple[set, dict]:
        """Compare each placement group's digest across every node that
        should hold it (its members — the group key IS the placement
        tuple) and every node that reports content for it (a stray holder
        left behind by an interrupted rebalance). Returns
        ``(all_groups, {group: nodes_to_detail})`` for the groups whose
        digests disagree; a member with no reply is unknown and excluded,
        a replying member without the group digests as empty — exactly a
        mismatch when a peer holds content for it."""
        groups: set = set()
        for r in replies.values():
            groups.update(r.keys())
        out: dict = {}
        for g in sorted(groups, key=repr):
            have = {n for n, r in replies.items() if g in r}
            consider = have | {n for n in g if n in replies}
            if len(consider) < 2:
                continue
            digests = {replies[n].get(g, (0, 0)) for n in consider}
            if len(digests) > 1:
                out[g] = sorted(consider)
        return groups, out

    def _collect_summaries(self, kind: str) -> dict:
        """Collect per-group summaries from every live node; the heart of
        both full and incremental rounds. A full round (``since_epoch``
        None) is one probe wave. An incremental round is two:

        1. every node digests only its DIRTY groups (clean ones are
           skipped server-side and counted), and — for omap probes —
           lists its aged tombstones;
        2. for each group some peer DID report, every group member that
           replied but skipped it is re-probed ``summary_only`` for just
           those groups — otherwise ``_mismatched`` would read "skipped
           because clean" as "holds nothing" and repair against a hole.

        Groups clean on EVERY holder are never compared — the incremental
        win. A stray group whose content was never touched stays invisible
        to incremental rounds; the periodic full round still finds it."""
        c = self.cluster
        replies: dict = {}
        for nid in self._live():
            r = self._ask(
                nid,
                DigestRequest(kind=kind, cmap=c.cmap, since_epoch=self.since_epoch),
            )
            if r is None:
                continue
            replies[nid] = dict(r.groups)
            self.report.groups_digested += len(r.groups)
            self.report.groups_skipped += r.skipped_groups
            if kind == "omap":
                if r.tombstones:
                    self._aged_tombstones[nid] = dict(r.tombstones)
                self._tombstones_collected = True
        if self.since_epoch is not None:
            need: dict[str, set] = {}
            all_groups: set = set()
            for r in replies.values():
                all_groups.update(r)
            for g in all_groups:
                for member in g:
                    if member in replies and g not in replies[member]:
                        need.setdefault(member, set()).add(g)
            for nid in sorted(need):
                r = self._ask(
                    nid,
                    DigestRequest(
                        kind=kind,
                        cmap=c.cmap,
                        groups=tuple(sorted(need[nid], key=repr)),
                        summary_only=True,
                    ),
                )
                if r is not None:
                    replies[nid].update(r.groups)
                    self.report.groups_digested += len(r.groups)
        return replies

    # ------------------------------------------------- phase 1: OMAP repair
    def repair_omap(self) -> int:
        """Reconcile OMAP replica sets by name-placement-group digest diff;
        a replica missing an entry adopts it from a holder (the holder
        sends ``OmapPut(migrate=True)`` — its own shard read sender-side,
        the recipe traveling as a stored record). Must run before the
        audit: an owner replica that missed a commit while unreachable
        would otherwise under-count expected references and the audit
        would release live data."""
        c = self.cluster
        lost_before = self.report.unreachable
        replies = self._collect_summaries("omap")
        _, mismatched = self._mismatched(replies)
        repaired = 0
        for g, consider in mismatched.items():
            details: dict = {}
            for nid in consider:
                r = self._ask(nid, DigestRequest(kind="omap", cmap=c.cmap, groups=(g,)))
                if r is not None:
                    details[nid] = r.entries
            names: set = set()
            for entries in details.values():
                names.update(entries)
            for name in sorted(names):
                targets = place(name_fp(name), c.cmap)  # CURRENT map, not digest-time
                order = {t: i for i, t in enumerate(targets)}
                holders = [n for n in targets if name in details.get(n, ())]
                # Stray holders (an interrupted rebalance retained the
                # entry off-placement) are last-resort sources: without
                # them a move whose every delivery was lost would leave
                # the entry unreachable by name-hash lookup forever.
                holders += [
                    n for n in sorted(details)
                    if n not in targets and name in details[n]
                ]
                if not holders:
                    continue
                # Version authority: the replica holding the HIGHEST commit
                # version wins (every replace AND every delete bumps the
                # cluster-monotonic version), with placement order breaking
                # ties. Placement order alone is wrong precisely when
                # recovery matters: a primary that was down across a
                # replace holds the OLD version and would resurrect it
                # cluster-wide. Tombstones are records like any other: a
                # tombstone at the highest version is the authority (the
                # delete propagates, no resurrection), and a live recreate
                # above a tombstone's version wins right back.
                authority = min(
                    holders,
                    key=lambda n: (-details[n][name][1], order.get(n, len(targets))),
                )
                auth_version = details[authority][name][1]
                entry = c.nodes[authority].shard.omap_get(name)  # sender-local
                if entry is None:
                    continue
                for t in targets:
                    if t not in details or t == authority or not c.nodes[t].alive:
                        continue
                    held = details[t].get(name)
                    if held is not None and held[1] == auth_version:
                        continue  # replica already holds the authoritative version
                    if self._send(authority, t, OmapPut(entry, migrate=True)) is not None:
                        repaired += 1
                # A stray holding a STALE version upgrades in place too —
                # otherwise its group summary diverges forever and every
                # later round re-details the group. Strays holding nothing
                # adopt nothing: repair converges replicas, rebalance (or
                # reap) drains strays.
                for t in sorted(details):
                    if t in targets or t == authority or not c.nodes[t].alive:
                        continue
                    held = details[t].get(name)
                    if held is None or held[1] == auth_version:
                        continue
                    if self._send(authority, t, OmapPut(entry, migrate=True)) is not None:
                        repaired += 1
        # Any lost probe means a replica's OMAP state is unknown — a node
        # that silently missed commits could still be elected recipe owner
        # with incomplete recipes, so the audit must not run this round.
        self._omap_repair_complete = self.report.unreachable == lost_before
        self.report.omap_repaired += repaired
        return repaired

    # --------------------------------------------- phase 2: chunk digests
    def collect_digests(self) -> dict:
        """Per-placement-group chunk/CIT summaries from every live node.
        Kept separate from ``repair_chunks`` so a topology change between
        the two is an explicit, testable hazard."""
        self._chunk_digests = self._collect_summaries("chunks")
        return self._chunk_digests

    def repair_chunks(self) -> int:
        """Digest-diff repair: expand mismatched groups into detail
        listings, then ship every missing byte copy / CIT snapshot from a
        surviving holder to each live placement target. Placement is
        resolved against the CURRENT cluster map at send time, so entries
        migrated by a rebalance since digest collection are skipped rather
        than repaired to a stale target. Returns byte copies restored
        (the old ``scrub`` contract)."""
        c = self.cluster
        if not self._chunk_digests:
            self.collect_digests()
        groups, mismatched = self._mismatched(self._chunk_digests)
        self.report.groups_checked += len(groups)
        self.report.groups_mismatched += len(mismatched)
        restored = 0
        for g, consider in mismatched.items():
            details: dict = {}
            for nid in consider:
                r = self._ask(
                    nid, DigestRequest(kind="chunks", cmap=c.cmap, groups=(g,))
                )
                if r is not None:
                    details[nid] = r.entries
            fps: set = set()
            for entries in details.values():
                fps.update(entries)
            for fp in sorted(fps):
                restored += self._repair_fp(fp, details)
        self.report.chunks_repaired += restored
        return restored

    def _repair_fp(self, fp: Fingerprint, details: dict) -> int:
        """Repair one fingerprint from wire-learned detail: for each live
        CURRENT-map target missing bytes or the CIT entry, pick sources —
        preferring a holder that has BOTH — and ship ``RepairChunk``. The
        CIT snapshot is built from the digest detail, never read from a
        foreign shard; the chunk bytes are the sending holder's own disk."""
        c = self.cluster
        absent = (False, False, 0, INVALID, 0, 0)
        has_bytes = [n for n, e in details.items() if e.get(fp, absent)[0]]
        has_cit = [n for n, e in details.items() if e.get(fp, absent)[1]]

        def snap_from(nid: str) -> CITEntry:
            _, _, refcount, flag, size, _ = details[nid][fp]
            return CITEntry(
                refcount, flag, size, None if flag == VALID else c.now
            )

        restored = 0
        for t in place(fp, c.cmap):
            if t not in details or not c.nodes[t].alive:
                continue  # unknown state (joined after digests) or down
            t_bytes, t_cit = details[t].get(fp, absent)[:2]
            need_bytes, need_cit = not t_bytes, not t_cit
            if not (need_bytes or need_cit):
                continue
            # Prefer a single holder carrying both bytes and metadata —
            # the fix for the old scrub's have[0] bug, which snapshotted
            # the CIT from an arbitrary holder even when it had no entry.
            full = [n for n in has_bytes if n in has_cit and n != t]
            if need_bytes:
                src = full[0] if full else next(
                    (n for n in has_bytes if n != t), None
                )
                data = (
                    c.nodes[src].chunk_store.get(fp)  # sender-local disk read
                    if src is not None
                    else None
                )
                if src is None:
                    # bytes survive on no holder; a surviving CIT entry is
                    # still repaired below so the group's digests converge
                    self.report.unrecoverable += 1
                elif data is not None:  # None = raced away since the digest
                    snap = snap_from(src) if src in has_cit and need_cit else None
                    resp = self._send(src, t, RepairChunk(fp, data, snap))
                    if resp is not None and resp[0] == "stored":
                        restored += 1
                        self.report.repair_bytes += len(data)
                    if resp is not None and resp[1] == "cit_stored":
                        self.report.cit_repaired += 1
                        need_cit = False
                    if snap is not None:
                        need_cit = False  # attempted with the bytes already
            if need_cit and has_cit:
                src = next((n for n in has_cit if n != t), None)
                if src is None:
                    continue
                resp = self._send(src, t, RepairChunk(fp, None, snap_from(src)))
                if resp is not None and resp[1] == "cit_stored":
                    self.report.cit_repaired += 1
        return restored

    # ------------------------------------------------- phase 3: ref audit
    def audit_refcounts(self) -> int:
        """Cluster-wide refcount audit. Expected counts walk the recipes
        by name-hash owner (one live owner per logical object); actual
        counts come from full CIT detail digests. Divergence becomes
        correction messages:

        * actual > expected — references no recipe accounts for (the lost
          TxnCancel leak, rolled-back garbage): an audit-tagged
          ``DecrefBatch`` releases the excess, and entries driven to zero
          skip the GC aging wait (the recipe walk is the cross-match).
        * actual < expected — a replica that missed increfs while
          unreachable: ``RefAudit`` raises it.
        * stuck INVALID with live recipes and bytes on disk — ``RefAudit``
          flips the flag (the lost-async-flip repair, audit flavor).

        Safety gate: if ANY live node's recipe digest is lost — or the
        round's OMAP repair phase lost probes, leaving replicas possibly
        unrepaired — the audit is skipped: partial expected counts would
        release references belonging to the unheard node's objects.

        Concurrency gate (``exclude_after``): a fingerprint whose CIT
        entry ANY replica mutated at or after the round's start epoch may
        belong to a transaction still completing — its refs were taken but
        its commit (or its async flag flip) has not landed, so the recipe
        walk would misread it as leaked. Such fingerprints are deferred to
        the next round (counted as ``audit_deferred``), which lets the
        audit run CONCURRENTLY with live writes instead of requiring a
        quiesced cluster."""
        if self._omap_repair_complete is False:
            self.report.audit_skipped = True
            return 0
        c = self.cluster
        live = tuple(sorted(self._live()))
        expected: dict[Fingerprint, int] = {}
        for nid in live:
            r = self._ask(
                nid, DigestRequest(kind="recipes", cmap=c.cmap, live=live)
            )
            if r is None:
                self.report.audit_skipped = True
                return 0
            for fp, n in r.entries.items():
                expected[fp] = expected.get(fp, 0) + n
        actual: dict[str, dict] = {}
        for nid in live:
            r = self._ask(
                nid, DigestRequest(kind="chunks", cmap=c.cmap, detail_all=True)
            )
            if r is not None:
                actual[nid] = r.entries

        young: set = set()
        if self.exclude_after is not None:
            for nid in actual:
                for fp, d in actual[nid].items():
                    if d[5] >= self.exclude_after:
                        young.add(fp)
            self.report.audit_deferred += len(young)
        # Sent-but-uncommitted waves (a Scheduler session yielded between
        # its send and commit phases): their chunk mtimes can PREDATE the
        # round start, so the epoch gate above misses them, yet their refs
        # have no committed recipe — the recipe walk would misread them as
        # leaked and decref live data. This is the coordinator's own
        # in-flight transaction knowledge (same authority as
        # ``exclude_after``), not cross-node state: the synchronous write
        # path commits in the same call as its send, so the set is always
        # empty outside scheduled runs.
        inflight = getattr(c, "inflight_audit_fps", None)
        if inflight is not None:
            fresh = inflight() - young
            if fresh:
                young |= fresh
                self.report.audit_deferred += len(fresh)

        decrefs: dict[str, list[Fingerprint]] = {}
        corrections: dict[str, list] = {}
        for nid in sorted(actual):
            for fp in sorted(actual[nid]):
                if fp in young:
                    continue
                _, has_cit, refcount, flag, _, _ = actual[nid][fp]
                targets = place(fp, c.cmap)  # CURRENT map: migrated chunks
                if nid not in targets:
                    continue  # stray awaiting rebalance — not audit's call
                exp = expected.get(fp, 0)
                if not has_cit:
                    if exp > 0:
                        self.report.missing_entries += 1
                    continue
                if refcount > exp:
                    decrefs.setdefault(nid, []).extend([fp] * (refcount - exp))
                    self.report.refs_over += refcount - exp
                elif refcount < exp:
                    corrections.setdefault(nid, []).append((fp, exp))
                    self.report.refs_under += exp - refcount
                elif exp > 0 and flag == INVALID and actual[nid][fp][0]:
                    corrections.setdefault(nid, []).append((fp, exp))
                    self.report.flags_flipped += 1

        for nid, fps in decrefs.items():
            if self._send(self.src, nid, DecrefBatch(tuple(fps), audit=True)) is not None:
                self.report.audit_msgs += 1
        for nid, items in corrections.items():
            if self._send(self.src, nid, RefAudit(tuple(items))) is not None:
                self.report.audit_msgs += 1
        return self.report.corrections

    # ------------------------------------------- phase 4: tombstone reap
    def reap_tombstones(self) -> int:
        """GC-horizon tombstone reap, gated on cluster-wide full-ack proof:
        a tombstone is reaped only when EVERY live placement target under
        the current map listed it as aged at the SAME version — i.e. the
        delete is fully replicated and no stale live replica remains for
        it to beat. Anything less (a target unreachable, still holding the
        live entry, or holding a different version) keeps the tombstone
        for the next round; repair converges the replicas first. The reap
        itself is version-conditional at the receiver, so a recreate that
        lands between proof and reap survives.

        A successful reap's response carries the tombstone's retained
        chunk fingerprints (the deleted recipe); the coordinator fans them
        out as ``PresenceInvalidate`` to registered client sessions — the
        last-chance invalidation for a delete whose original fan-out was
        lost (e.g. the session was partitioned away when the delete ran)."""
        c = self.cluster
        if not self._tombstones_collected:
            self._collect_summaries("omap")
        candidates: dict[str, dict[str, int]] = {}
        for nid, tombs in self._aged_tombstones.items():
            for name, (version, _at) in tombs.items():
                candidates.setdefault(name, {})[nid] = version
        reaped = 0
        reap_fps: set = set()
        for name in sorted(candidates):
            listers = candidates[name]
            if len(set(listers.values())) != 1:
                continue  # replicas disagree on the delete: repair first
            version = next(iter(listers.values()))
            targets = [
                t for t in place(name_fp(name), c.cmap) if c.nodes[t].alive
            ]
            if not targets or any(t not in listers for t in targets):
                continue  # not fully acked by every live placement target
            for t in sorted(listers):
                if not c.nodes[t].alive:
                    continue
                resp = self._send(self.src, t, TombstoneReap(name, version))
                if isinstance(resp, tuple) and resp[0] == "reaped":
                    reaped += 1
                    reap_fps.update(resp[1])
        if reap_fps:
            c._invalidate_presence(self.src, tuple(sorted(reap_fps)), "reap")
        self.report.tombstones_reaped += reaped
        return reaped

    # ------------------------------------------------------- phase 5: GC
    def collect_garbage(self, rounds: int = 2) -> int:
        """Reclaim what the audit tombstoned (pre-aged: collected on the
        first sweep) plus ordinary aged garbage, to a fixed point."""
        c = self.cluster
        removed = sum(len(fps) for fps in c.run_gc().values())
        threshold = max(
            (n.gc.threshold for n in c.nodes.values()), default=10
        )
        for _ in range(rounds):
            c.tick(threshold + 1)
            removed += sum(len(fps) for fps in c.run_gc().values())
        self.report.gc_removed += removed
        return removed

    # ------------------------------------------------------------ full run
    def run(self) -> RecoveryReport:
        self.repair_omap()
        self.collect_digests()
        self.repair_chunks()
        self.audit_refcounts()
        self.reap_tombstones()
        self.collect_garbage()
        return self.report


@dataclass
class RepairDaemon:
    """Always-on incremental repair: runs epoch-scoped recovery rounds
    concurrently with live traffic instead of waiting for an operator's
    post-mortem ``recover()``.

    Each ``step()`` starts a round at the current sim time and scopes it
    two ways: digests cover only placement groups dirtied at or after the
    LAST COMPLETED round's start (``since_epoch`` — the dirty trackers
    make clean groups free), and the refcount audit defers fingerprints
    mutated at or after THIS round's start (``exclude_after`` — in-flight
    transactions are never misjudged). GC runs one un-forced sweep per
    step — aging happens on the cluster's own clock, the daemon doesn't
    fast-forward time the way the post-mortem path does.

    The epoch floor only advances when a round heard every node: a round
    with lost probes repairs what it can but the next round re-covers the
    same window, so missed dirt cannot slip between rounds."""

    cluster: object
    last_completed: int = 0
    rounds_run: int = 0
    reports: list = field(default_factory=list)

    def step(self) -> RecoveryReport:
        c = self.cluster
        start = c.now
        r = RecoveryRound(c, since_epoch=self.last_completed, exclude_after=start)
        r.repair_omap()
        r.collect_digests()
        r.repair_chunks()
        r.audit_refcounts()
        r.reap_tombstones()
        removed = sum(len(fps) for fps in c.run_gc().values())
        r.report.gc_removed += removed
        if r.report.unreachable == 0:
            self.last_completed = start
        self.rounds_run += 1
        self.reports.append(r.report)
        return r.report

    def actor(self, interval: int):
        """This daemon as a discrete-event actor: one ``step()`` per
        ``interval`` ticks, forever. Register on a Scheduler with
        ``sched.spawn(daemon.actor(50), name="repair")`` — or use
        ``sched.every(interval, daemon.step, name="repair")``, which is
        the same shape; this helper exists so the daemon's cadence can
        live with the daemon. Repair rounds then interleave with live
        client sessions on the shared event heap (docs/concurrency.md)
        instead of running only when a test harness remembers to call
        ``step()`` between its own operations."""
        while True:
            self.step()
            yield interval


def run_recovery(cluster) -> RecoveryReport:
    """Full post-failure reconciliation round (the split-brain heal path):
    OMAP repair -> digest-diff chunk repair -> cluster-wide refcount audit
    -> tombstone reap -> GC."""
    return RecoveryRound(cluster).run()


def repair_round(cluster) -> int:
    """Digest-driven re-replication repair (the ``scrub`` contract):
    returns chunk byte copies restored."""
    r = RecoveryRound(cluster)
    r.collect_digests()
    return r.repair_chunks()


def rebalance(cluster) -> None:
    """Storage rebalance after a topology change (paper Fig 1b), driven
    per node: every node pushes its own misplaced chunks (with their CIT
    entries — content placement means metadata moves with content, never
    by location rewrite), stray tombstones, and OMAP entries to the new
    placement targets, as ``MigrateChunk`` / ``OmapPut(migrate=True)``
    unicasts. All reads are sender-local (a node reading its own disk and
    shard to build its outgoing messages).

    Loss discipline: the source RETAINS its local copy until at least one
    move is acked — a lossy policy that eats every ``MigrateChunk`` must
    not erase the last surviving copy (the old pop-first order destroyed
    data irrecoverably under replicas=1 + a drop policy). A retained
    off-placement copy is a stray holder: the digest repair round
    discovers it (strays join the group comparison) and re-ships it to
    the proper targets, and the next rebalance retries the move."""
    new_map = cluster.cmap
    for nid, node in list(cluster.nodes.items()):
        if not node.alive:
            continue
        # --- migrate chunks + their CIT entries --------------------------
        for fp in list(node.chunk_store.keys()):
            targets = place(fp, new_map)
            if nid in targets:
                continue
            data = node.chunk_store[fp]
            entry = node.shard.cit_lookup(fp)
            snap = entry.snapshot() if entry is not None else None
            moved = False
            delivered = False
            for t in targets:
                if not cluster.nodes[t].alive:
                    continue
                needs_bytes = fp not in cluster.nodes[t].chunk_store
                msg = MigrateChunk(fp, data if needs_bytes else None, snap)
                try:
                    cluster.transport.send(nid, t, msg, cluster.now)
                except (MessageDropped, NodeDown):
                    continue
                delivered = True
                if needs_bytes:
                    moved = True
            if not delivered:
                continue  # nothing acked: keep the local copy (stray holder)
            node.chunk_store.pop(fp)
            if entry is not None:
                node.shard.cit_remove(fp)
            if moved:
                cluster.stats.rebalance_chunks_moved += 1
                cluster.stats.rebalance_bytes_moved += len(data)
        # --- stray CIT entries without local bytes (tombstones) ---------
        for fp in list(node.shard.cit.keys()):
            targets = place(fp, new_map)
            if nid in targets:
                continue
            entry = node.shard.cit_lookup(fp)
            if entry is None:
                continue
            snap = entry.snapshot()
            delivered = False
            for t in targets:
                if not cluster.nodes[t].alive:
                    continue
                try:
                    cluster.transport.send(
                        nid, t, MigrateChunk(fp, None, snap), cluster.now
                    )
                except (MessageDropped, NodeDown):
                    continue
                delivered = True
            if delivered:
                node.shard.cit_remove(fp)
        # --- migrate OMAP entries by object-name hash --------------------
        for name in list(node.shard.omap.keys()):
            targets = place(name_fp(name), new_map)
            if nid in targets:
                continue
            e = node.shard.omap_get(name)
            assert e is not None
            delivered = False
            for t in targets:
                if not cluster.nodes[t].alive:
                    continue
                try:
                    cluster.transport.send(
                        nid, t, OmapPut(e, migrate=True), cluster.now
                    )
                except (MessageDropped, NodeDown):
                    continue
                delivered = True
            if delivered:
                node.shard.omap_delete(name)
