"""SimClock + deterministic Scheduler — the discrete-event simulation core.

Before this module, execution was call-driven: the test harness (or a
bench) called ``cluster.tick()``, ``run_gc()``, ``RepairDaemon.step()``
and each client's writes in whatever order it remembered, so exactly one
thing ever ran "at a time" and the per-edge stats / straggler-NIC model
had no concurrency to measure (ROADMAP item 1). The Scheduler inverts
that: client sessions, GC sweeps, repair rounds and time advancement are
all *actors* on one event heap, and the Scheduler alone advances the
cluster clock (``cluster.tick`` — which drains ``Transport.advance``
late-delivery copies and every node's ConsistencyManager flip queue)
between events. N client sessions genuinely interleave: wave k of
session A is in flight (sent, un-committed) while session B chunks and
sends its own wave at the same tick.

Determinism argument (the property every test leans on):

* the event heap orders by ``(time, tiebreak, seq)`` where ``tiebreak``
  is drawn from a ``random.Random(seed)`` at push time and ``seq`` is a
  monotonic push counter — so ties at one tick are broken by the seeded
  stream, reproducibly, and two runs with the same seed pop events in
  the identical order;
* actors are cooperative generators — no threads, no wall clock, no OS
  scheduling anywhere;
* everything else in the system is already deterministic (seeded
  delivery policies, insertion-ordered dicts, no hash-order iteration).

Same seed ⇒ identical event log, stats snapshot and final cluster state;
a different seed is a different legal interleaving of the same ops —
which must (and does: tests/test_workload.py) converge to the same
per-name winners after recovery, because commit authority is the
cluster-monotonic version counter, not arrival order.

Retransmission timeouts stay *inside* ``Transport.send`` (a sender
synchronously waits out ``ack_timeout`` ticks per attempt, booked in
``timeout_ticks_waited``): hoisting them onto the heap would change the
message sequence of every existing chaos schedule, and the parity pin —
single-session scheduled runs must be message-identical to the
call-driven path — forbids that. The send-level wait models a blocked
client thread, which is exactly what it is.

Clock skew: ``SimClock`` carries per-node bounded offsets mirroring
``StorageNode.clock_offset`` (configure both via
``Scheduler.set_clock_skew`` / ``DedupCluster.set_clock_skew``). Offsets
apply ONLY where a real deployment would read a wall clock — tombstone
``deleted_at`` stamping and tombstone aging — never to delivery order or
version authority. See docs/concurrency.md.
"""

from __future__ import annotations

import heapq
import random
from dataclasses import dataclass, field


@dataclass
class SimClock:
    """Monotonic simulated event time plus per-node bounded clock offsets.

    ``now`` is the single event-time axis every actor shares; a node's
    *local* clock reads ``node_now(nid) = now + offsets[nid]`` (the
    skewed reading ``StorageNode.local_now`` applies to tombstone
    stamping/aging). ``max_skew`` is the bound the reap guard widens the
    GC horizon by."""

    now: int = 0
    offsets: dict[str, int] = field(default_factory=dict)

    def advance(self, dt: int) -> int:
        if dt < 0:
            raise ValueError("SimClock is monotonic: dt must be >= 0")
        self.now += dt
        return self.now

    def node_now(self, nid: str) -> int:
        return self.now + self.offsets.get(nid, 0)

    @property
    def max_skew(self) -> int:
        return max((abs(v) for v in self.offsets.values()), default=0)


@dataclass(order=True)
class _Event:
    time: int
    tiebreak: float
    seq: int
    name: str = field(compare=False)


class Scheduler:
    """Deterministic discrete-event scheduler over one ``DedupCluster``.

    Actors are generators yielding integer tick delays (``yield 3`` =
    "resume me 3 ticks from now"; a bare ``yield`` means 1). ``spawn``
    registers a one-shot actor (runs to ``StopIteration``; its return
    value lands in ``results[name]``); ``every`` registers a recurring
    actor around a plain callable (GC sweep, ``RepairDaemon.step``).

    ``run()`` is run-to-quiescence: process events until no ONE-SHOT
    actor remains runnable (recurring actors alone don't keep the
    simulation alive — they exist to interleave with the real work),
    then keep ticking until the wire is quiet (no held transport copies)
    and every live node's flip queue is drained. ``run_until(t)``
    processes everything due through ``t`` and leaves the clock there.

    The event log records, per actor step, ``(time, actor, in-flight
    session labels)`` — the labels are the registered sessions whose
    ``in_flight`` flag was set *after* the step, so
    ``max_in_flight_sessions >= 2`` is the witness that two sessions
    had sent-but-uncommitted waves at the same tick (the acceptance
    criterion's interleaving proof)."""

    def __init__(self, cluster, seed: int = 0):
        self.cluster = cluster
        self.seed = seed
        self.clock = SimClock(
            now=cluster.now,
            offsets={
                nid: n.clock_offset
                for nid, n in cluster.nodes.items()
                if n.clock_offset
            },
        )
        self._rng = random.Random(seed)
        self._heap: list[_Event] = []
        self._actors: dict[str, object] = {}      # name -> generator
        self._recurring: set[str] = set()
        self._sessions: dict[str, object] = {}    # label -> DedupClient
        self._seq = 0
        self._live_oneshot = 0
        self.results: dict[str, object] = {}
        self.errors: dict[str, Exception] = {}
        self.event_log: list[tuple[int, str, tuple[str, ...]]] = []
        self.steps = 0

    # ------------------------------------------------------------- registration
    def spawn(self, gen, name: str, delay: int = 0, session=None) -> None:
        """Register a one-shot generator actor; first step after ``delay``
        ticks. ``session`` (a ``DedupClient``) makes the actor's session
        visible to the in-flight log under label ``name``."""
        if name in self._actors:
            raise ValueError(f"actor {name!r} already registered")
        self._actors[name] = gen
        if session is not None:
            self._sessions[name] = session
        self._live_oneshot += 1
        self._push(self.cluster.now + max(0, delay), name)

    def every(self, interval: int, fn, name: str, start: int | None = None) -> None:
        """Register a recurring actor: call ``fn()`` every ``interval``
        ticks (first call after ``start`` ticks, default one interval).
        Recurring actors interleave with session actors but do not keep
        ``run()`` alive on their own."""
        if interval <= 0:
            raise ValueError("recurring interval must be positive")

        def _loop():
            while True:
                fn()
                yield interval

        if name in self._actors:
            raise ValueError(f"actor {name!r} already registered")
        self._actors[name] = _loop()
        self._recurring.add(name)
        self._push(
            self.cluster.now + (interval if start is None else max(0, start)), name
        )

    def set_clock_skew(self, offsets: dict[str, int], guard: bool = True) -> int:
        """Install bounded per-node clock offsets on the cluster (see
        ``DedupCluster.set_clock_skew``) and mirror them on ``clock``."""
        self.clock.offsets = {k: v for k, v in offsets.items() if v}
        return self.cluster.set_clock_skew(offsets, guard=guard)

    # ------------------------------------------------------------------ running
    def run(self, max_time: int = 1_000_000) -> dict:
        """Run to quiescence (see class docstring). Returns ``results``."""
        while self._live_oneshot > 0 and self._heap:
            if self._heap[0].time > max_time:
                raise RuntimeError(
                    f"scheduler exceeded max_time={max_time} with "
                    f"{self._live_oneshot} one-shot actor(s) still live"
                )
            self._step()
        self._settle(max_time)
        return self.results

    def run_until(self, t_end: int) -> dict:
        """Process every event due at or before ``t_end``, then advance
        the clock to exactly ``t_end`` (late copies land, flip queues
        drain through that tick)."""
        while self._heap and self._heap[0].time <= t_end:
            self._step()
        self._advance_to(t_end)
        return self.results

    @property
    def max_in_flight_sessions(self) -> int:
        """Peak count of sessions with a sent-but-uncommitted wave at one
        logged step — >= 2 proves genuine interleaving."""
        return max((len(e[2]) for e in self.event_log), default=0)

    # ---------------------------------------------------------------- internals
    def _push(self, t: int, name: str) -> None:
        self._seq += 1
        heapq.heappush(self._heap, _Event(t, self._rng.random(), self._seq, name))

    def _advance_to(self, t: int) -> None:
        c = self.cluster
        if t > c.now:
            c.tick(t - c.now)
        self.clock.now = c.now

    def _in_flight_labels(self) -> tuple[str, ...]:
        return tuple(
            sorted(
                label
                for label, s in self._sessions.items()
                if getattr(s, "in_flight", 0)
            )
        )

    def _step(self) -> None:
        ev = heapq.heappop(self._heap)
        gen = self._actors.get(ev.name)
        if gen is None:
            return  # actor already finished/failed (stale heap entry)
        self._advance_to(ev.time)
        self.steps += 1
        recurring = ev.name in self._recurring
        try:
            delay = next(gen)
        except StopIteration as stop:
            self.results[ev.name] = stop.value
            self._retire(ev.name, recurring)
        except Exception as exc:  # actor died: record, don't kill the sim
            self.errors[ev.name] = exc
            self._retire(ev.name, recurring)
        else:
            self._push(ev.time + max(1, int(delay) if delay is not None else 1),
                       ev.name)
        self.event_log.append((ev.time, ev.name, self._in_flight_labels()))

    def _retire(self, name: str, recurring: bool) -> None:
        del self._actors[name]
        if recurring:
            self._recurring.discard(name)
        else:
            self._live_oneshot -= 1

    def _settle(self, max_time: int) -> None:
        """Quiescence tail: tick until nothing is on the wire and every
        live node's consistency queue is drained (bounded by the pending
        flips' own due-times plus one tick per held copy, so this cannot
        spin)."""
        c = self.cluster
        guard = 0
        while c.now < max_time and guard < 10_000:
            held = c.transport.in_flight_copies()
            pending = [
                n.cm.next_due() for n in c.nodes.values() if n.alive and n.cm.pending()
            ]
            if not held and not pending:
                break
            target = c.now + 1
            due = [d for d in pending if d is not None]
            if not held and due:
                target = max(target, min(due))
            self._advance_to(min(target, max_time))
            guard += 1
        self.clock.now = c.now
