"""Client-side write-back chunk cache and fingerprint presence cache.

Two bounded host-side structures, modeled on s3ql's ``block_cache``
(bounded dirty set, upload in waves, explicit flush/invalidation) and the
casstor ``existing_blocks`` distributed-set idea, that close ROADMAP open
item 2:

* ``WriteBackCache`` — the dirty-chunk staging buffer. ``write_objects``
  used to materialize every chunk for the whole batch up front (~2x batch
  bytes of peak host memory); the cache instead chunks + fingerprints
  lazily, emitting bounded *waves*: while wave k's ``ChunkOpBatch``es are
  on the wire, only wave k's chunks are resident, so a multi-GB ingest
  holds O(wave) not O(batch) host memory. ``peak_dirty_bytes`` records
  the high-water mark (a deterministic function of the workload).

* ``PresenceCache`` — a bounded LRU set of fingerprints the client has
  POSITIVE wire evidence for: every acked chunk op whose outcome proves
  the chunk stored cluster-wide ('stored'/'restored'/'dedup_hit'/
  'repaired') teaches the cache. A later write of the same content sends
  a presence-asserted ref-only op (``ChunkOp(presence=True)``): no chunk
  bytes travel and the op is excluded from the CIT-probe accounting
  (``ChunkOpBatch.lookups()``) — the probe-elision win on repeat-heavy
  traffic.

Safety argument (the part chaos policies must not break): presence is an
*optimization hint*, never an authority. The receiving CIT owner always
validates a presence-asserted op against its own shard and answers
``'miss'`` when the entry is gone or invalid without local bytes; the
writer then falls back to shipping the chunk bytes (``_write_wave``'s
fallback resend). So a stale cache — invalidation lost, delayed,
reordered, or duplicated — degrades to exactly the pre-cache probe path
and can never mint a dangling reference. ``PresenceInvalidate`` fan-outs
(on delete, GC reclaim, and tombstone reap) exist to keep the hit rate
honest, not to keep the cluster correct; see docs/write_cache.md.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable, Iterable, Iterator

from repro.core.chunking import ChunkingSpec, chunk_object
from repro.core.fingerprint import Fingerprint, fingerprint_many

# Outcomes that prove a chunk is stored (bytes + CIT entry) on its owner —
# the only evidence the presence cache accepts. Batched restore hits
# (``ChunkReadBatchReply`` chunks) carry the same proof — the bytes were
# just served from their owner — so ``read_objects`` teaches sessions per
# acked hit through the same ``note()`` path.
PRESENCE_OUTCOMES = frozenset({"stored", "restored", "dedup_hit", "repaired"})


class PresenceCache:
    """Bounded LRU set of fingerprints with positive existence evidence.

    ``sink`` (optional) is any object with ``cache_hits`` /
    ``cache_misses`` / ``cache_evictions`` / ``cache_invalidations``
    integer attributes — in practice the cluster's ``ClusterStats`` — so
    per-session activity lands in the cluster-wide deterministic columns
    as it happens. The cache also keeps its own counters for standalone
    inspection."""

    def __init__(self, capacity: int, sink: object | None = None):
        if capacity <= 0:
            raise ValueError("PresenceCache capacity must be positive")
        self.capacity = capacity
        self.sink = sink
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0
        self._fps: OrderedDict[Fingerprint, None] = OrderedDict()

    def _bump(self, name: str, n: int = 1) -> None:
        if self.sink is not None:
            setattr(self.sink, name, getattr(self.sink, name) + n)

    def __len__(self) -> int:
        return len(self._fps)

    def __contains__(self, fp: Fingerprint) -> bool:
        return fp in self._fps

    def hit(self, fp: Fingerprint) -> bool:
        """Query for a write decision: True moves ``fp`` to MRU and counts
        a hit; False counts a miss (the op takes the ordinary probe path)."""
        if fp in self._fps:
            self._fps.move_to_end(fp)
            self.hits += 1
            self._bump("cache_hits")
            return True
        self.misses += 1
        self._bump("cache_misses")
        return False

    def note(self, fp: Fingerprint) -> None:
        """Record positive evidence (an acked storing outcome, or a
        batched read hit) for ``fp``; evicts the LRU entry beyond
        capacity."""
        if fp in self._fps:
            self._fps.move_to_end(fp)
            return
        self._fps[fp] = None
        while len(self._fps) > self.capacity:
            self._fps.popitem(last=False)
            self.evictions += 1
            self._bump("cache_evictions")

    def drop(self, fp: Fingerprint) -> bool:
        """Invalidate one fingerprint (idempotent)."""
        if self._fps.pop(fp, True) is None:
            self.invalidations += 1
            self._bump("cache_invalidations")
            return True
        return False

    def invalidate_many(self, fps: Iterable[Fingerprint]) -> int:
        """Apply a ``PresenceInvalidate`` fan-out; duplicates and unknown
        fingerprints are no-ops, so redelivery under chaos is harmless."""
        return sum(1 for fp in fps if self.drop(fp))

    def clear(self) -> None:
        self.invalidations += len(self._fps)
        self._bump("cache_invalidations", len(self._fps))
        self._fps.clear()


@dataclass
class WriteBackCache:
    """Bounded dirty-chunk staging buffer: turns an object batch into
    bounded, lazily prepared write waves.

    ``wave_bytes`` bounds the chunk bytes resident per wave (0 =
    unbounded, one wave per name-repeat segment — the legacy shape). A
    wave always admits at least one object, so a single object larger
    than the bound still writes (one-object wave); waves additionally
    split at a repeated object name, preserving ``write_objects``'s
    last-write-wins ordering guarantee. ``sink`` is the same stats object
    ``PresenceCache`` uses (``peak_dirty_bytes`` attribute)."""

    chunking: ChunkingSpec
    wave_bytes: int = 0
    sink: object | None = None
    dirty_bytes: int = 0
    peak_dirty_bytes: int = 0
    waves_emitted: int = 0

    def _note_dirty(self, nbytes: int) -> None:
        self.dirty_bytes += nbytes
        if self.dirty_bytes > self.peak_dirty_bytes:
            self.peak_dirty_bytes = self.dirty_bytes
            if self.sink is not None and self.dirty_bytes > getattr(
                self.sink, "peak_dirty_bytes", 0
            ):
                self.sink.peak_dirty_bytes = self.dirty_bytes

    def release(self) -> None:
        """Wave handed to the transport and committed: its chunks are no
        longer resident."""
        self.dirty_bytes = 0

    def prepare(self, name: str, data: bytes) -> tuple:
        """Chunk + fingerprint one object into the dirty set."""
        chunks = chunk_object(data, self.chunking)
        self._note_dirty(sum(len(c) for c in chunks))
        fps = fingerprint_many(chunks)
        return (name, data, chunks, fps)

    def _prepare_wave(self, wave: list[tuple[str, bytes]]) -> list[tuple]:
        """Chunk every object of one wave, then fingerprint the wave's
        chunks in ONE vectorized pass (the legacy whole-batch shape, at
        wave granularity)."""
        prepped = [
            (name, data, chunk_object(data, self.chunking))
            for name, data in wave
        ]
        for _, _, chunks in prepped:
            self._note_dirty(sum(len(c) for c in chunks))
        all_fps = fingerprint_many(
            [c for _, _, chunks in prepped for c in chunks]
        )
        out: list[tuple] = []
        off = 0
        for name, data, chunks in prepped:
            out.append((name, data, chunks, all_fps[off : off + len(chunks)]))
            off += len(chunks)
        self.waves_emitted += 1
        return out

    def waves(
        self, items: Iterable[tuple[str, bytes]]
    ) -> Iterator[list[tuple]]:
        """Lazily yield bounded, prepared write waves. Chunking +
        fingerprinting for wave k+1 happen only after wave k was yielded
        (and its dirty bytes released), which is the streaming-overlap
        seam: wave k is on the wire while k+1 is being chunked. Chunking
        is lossless, so an object's chunk bytes equal its data bytes and
        the bound can be checked before chunking.

        ``DedupClient.put_wave_actor`` drives this generator from the
        discrete-event Scheduler: resuming it chunks wave k+1 while wave
        k's sends are still uncommitted (``stats.waves_overlapped``),
        and the synchronous ``put_many`` path consumes it eagerly — the
        two orders are message-identical because chunking emits no
        messages (docs/concurrency.md)."""
        wave: list[tuple[str, bytes]] = []
        names_in_wave: set[str] = set()
        pending = 0
        for name, data in items:
            full = (
                self.wave_bytes > 0
                and wave
                and pending + len(data) > self.wave_bytes
            )
            if full or name in names_in_wave:
                yield self._prepare_wave(wave)
                self.release()
                wave, names_in_wave, pending = [], set(), 0
            wave.append((name, data))
            names_in_wave.add(name)
            pending += len(data)
        if wave:
            yield self._prepare_wave(wave)
            self.release()


@dataclass
class PendingWrites:
    """The write-back buffer behind ``DedupClient.put``: objects accepted
    but not yet written. ``flush_threshold`` (0 = never) auto-flushes via
    ``on_flush`` once the buffered object bytes reach the bound — the
    s3ql dirty-set discipline at object granularity."""

    flush_threshold: int = 0
    on_flush: Callable[[list[tuple[str, bytes]]], None] | None = None
    items: list[tuple[str, bytes]] = field(default_factory=list)
    buffered_bytes: int = 0

    def add(self, name: str, data: bytes) -> None:
        self.items.append((name, data))
        self.buffered_bytes += len(data)
        if (
            self.flush_threshold > 0
            and self.buffered_bytes >= self.flush_threshold
            and self.on_flush is not None
        ):
            self.on_flush(self.drain())

    def drain(self) -> list[tuple[str, bytes]]:
        items, self.items = self.items, []
        self.buffered_bytes = 0
        return items

    def __len__(self) -> int:
        return len(self.items)
