"""DedupCluster — the shared-nothing cluster with cluster-wide deduplication.

Implements the paper's complete write/read I/O transactions (Fig 3), the
fingerprint-routed chunk placement (Fig 2), storage rebalancing on topology
change (Fig 1b, made metadata-free by content placement), K-way replication,
failure injection, and byte-accurate network/disk accounting for the
benchmark models.

Transaction flow (write) — every arrow is a typed message on the Transport
(see core/messages.py for the catalog, core/transport.py for delivery):

  client --(object bytes: ingress transfer)--> primary OSS (by name hash)
  primary: chunk + fingerprint (vectorized, whole batch at once), then
      OmapGet           -> idempotence / replace check
      ChunkOpBatch      -> one unicast per *target node* carrying every
                           chunk op routed there — for the WHOLE batch of
                           objects, not per object (cross-object unicast
                           coalescing). A batch-local fp->first-writer
                           cache turns intra-batch duplicate chunks into
                           ref-only ops before anything hits the wire.
      target: CIT lookup -> dedup_hit | repaired | restored | stored
                           (commit flags flip asynchronously, paper §2.4)
  per object, once its chunk ops are acked:
      OmapPut           -> OMAP entry on primary (+ replicas) = txn commit
  on failure: DecrefBatch rolls back the refs the failed object took;
      unreachable decrements leave flag-0 garbage for GC (paper's model).

Each object in a batch remains its own transaction: a failure raises at
that object after earlier objects committed — retrying the tail reproduces
the serial outcome exactly.

Failure surface: a fault injector callback may crash nodes / abort between
steps (the legacy event points), and the transport's delivery policy may
drop, delay, or partition messages (the message-level failure space). When
a fault injector is listening, writes auto-select the chunk-granular
message shape so every per-chunk event window stays observable.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

from repro.core.chunking import ChunkingSpec
from repro.core.dmshard import OMAPEntry
from repro.core.fingerprint import (
    Fingerprint,
    name_fp,
    object_fp,
)
from repro.core.messages import (
    CONTROL_MSG_BYTES,
    ChunkOp,
    ChunkOpBatch,
    ChunkRead,
    ChunkReadBatch,
    DecrefBatch,
    OmapDelete,
    OmapGet,
    OmapPut,
    PresenceInvalidate,
    RefOnlyWrite,
    TxnCancel,
)
from repro.core.node import ChunkMissing, NodeDown, StorageNode
from repro.core.placement import ClusterMap, place
from repro.core.transport import MessageDropped, Transport

# fault injector signature: (event, context-dict) -> None. May raise
# TransactionAbort or call cluster.crash_node() to model failures.
FaultInjector = Callable[[str, dict], None]


class TransactionAbort(RuntimeError):
    pass


class WriteError(RuntimeError):
    pass


class ReadError(RuntimeError):
    pass


class ClusterStats:
    """Legacy stats facade. Transaction-outcome counters live here; all
    network/message counters are *views* over the Transport's accounting
    (legacy field names preserved — nothing hand-maintains them anymore)."""

    def __init__(self, transport: Transport, nodes: dict | None = None):
        self._transport = transport
        self._nodes = nodes if nodes is not None else {}
        self.logical_bytes_written = 0
        self.writes_ok = 0
        self.writes_failed = 0
        # Commit-version races under concurrent sessions: the write landed
        # (>=1 OMAP replica acked) but every replica's version gate refused
        # it because a concurrent committer got there with a newer version
        # first. Semantically a committed-then-instantly-replaced write:
        # counted in writes_ok, its refs rolled back, never readable.
        self.writes_superseded = 0
        self.reads_ok = 0
        self.rebalance_bytes_moved = 0
        self.rebalance_chunks_moved = 0
        # Scheduled-session pipelining: waves whose k+1 chunking ran while
        # wave k's chunk unicasts were still in flight (un-committed) — the
        # overlap the discrete-event scheduler buys (see docs/concurrency.md).
        self.waves_overlapped = 0
        # Write-back / presence cache counters (core/write_cache.py). The
        # caches of every DedupClient session on this cluster accumulate
        # here, so the columns are cluster-wide and survive session close.
        self.probe_elisions = 0        # CIT probes elided by presence hits
        self.cache_hits = 0            # presence-cache hits at plan time
        self.cache_misses = 0          # presence-cache misses at plan time
        self.cache_evictions = 0       # LRU evictions from presence caches
        self.cache_invalidations = 0   # fps dropped by PresenceInvalidate
        self.presence_fallbacks = 0    # stale presence -> byte resends
        self.peak_dirty_bytes = 0      # high-water dirty chunk bytes (host)
        # Coalesced restore engine counters (read_objects). fetch_elisions
        # is the read-side twin of probe_elisions: duplicate fingerprint
        # references inside one restore batch whose bytes were fetched once
        # and reused (the first-reader cache), never re-requested.
        self.read_batches = 0          # ChunkReadBatch unicasts planned
        self.read_fallback_rounds = 0  # follow-up waves re-requesting misses
        self.fetch_elisions = 0        # duplicate chunk fetches elided

    @property
    def net_bytes(self) -> int:
        """Payload bytes crossing the network (transport view)."""
        return self._transport.net_bytes

    @property
    def control_msgs(self) -> int:
        """Messages sent through the transport (lookup/ack/refcount/... )."""
        return self._transport.messages_sent

    @property
    def lookup_unicasts(self) -> int:
        return self._transport.lookup_unicasts

    @property
    def lookup_broadcasts(self) -> int:
        return self._transport.lookup_broadcasts  # always 0 — the paper's point

    # --- at-least-once delivery counters (transport views) -----------------
    @property
    def retransmits(self) -> int:
        """Wire-level re-sends chasing lost messages/acks (not counted in
        ``control_msgs``, which stays the logical message count)."""
        return self._transport.retransmits

    @property
    def acks(self) -> int:
        """Delivery acks sent back to senders (one per handler delivery,
        including duplicate/late copies)."""
        return self._transport.acks_sent

    @property
    def ack_bytes(self) -> int:
        """Wire bytes spent on acks — included in ``net_bytes``."""
        return self._transport.ack_bytes

    @property
    def msgs_dropped(self) -> int:
        return self._transport.dropped

    @property
    def duplicate_deliveries(self) -> int:
        """Extra copies that reached a handler (duplicate/reorder faults);
        the receivers' seen-windows made them state no-ops."""
        return self._transport.late_deliveries

    @property
    def timeout_ticks_waited(self) -> int:
        """Simulated ticks senders spent waiting on acks that never came."""
        return self._transport.timeout_ticks_waited

    # --- seen-window eviction pressure (per-node, aggregated) --------------
    @property
    def seen_evictions(self) -> int:
        """Message ids the bounded per-node seen-windows pushed out. Zero
        at default sizing; anything else means in-flight depth approached
        the point where a late duplicate could slip past dedup (the
        ROADMAP's seen-window sizing signal)."""
        return sum(n.stats.seen_evictions for n in self._nodes.values())

    @property
    def seen_high_water(self) -> int:
        """Peak seen-window occupancy across nodes — how close the cluster
        came to eviction pressure."""
        return max(
            (n.stats.seen_high_water for n in self._nodes.values()), default=0
        )

    def snapshot(self) -> dict:
        """One-call dict view of every counter — the stable consumption
        surface for benches and ``check_bench_regression.py`` (preferred
        over attribute-poking, which couples callers to which counters are
        plain fields vs transport views). Keys are the attribute names;
        values are plain ints, safe to serialize."""
        return {
            "logical_bytes_written": self.logical_bytes_written,
            "writes_ok": self.writes_ok,
            "writes_failed": self.writes_failed,
            "writes_superseded": self.writes_superseded,
            "waves_overlapped": self.waves_overlapped,
            "reads_ok": self.reads_ok,
            "rebalance_bytes_moved": self.rebalance_bytes_moved,
            "rebalance_chunks_moved": self.rebalance_chunks_moved,
            "net_bytes": self.net_bytes,
            "control_msgs": self.control_msgs,
            "lookup_unicasts": self.lookup_unicasts,
            "lookup_broadcasts": self.lookup_broadcasts,
            "retransmits": self.retransmits,
            "acks": self.acks,
            "ack_bytes": self.ack_bytes,
            "msgs_dropped": self.msgs_dropped,
            "duplicate_deliveries": self.duplicate_deliveries,
            "timeout_ticks_waited": self.timeout_ticks_waited,
            "seen_evictions": self.seen_evictions,
            "seen_high_water": self.seen_high_water,
            "probe_elisions": self.probe_elisions,
            "cache_hits": self.cache_hits,
            "cache_misses": self.cache_misses,
            "cache_evictions": self.cache_evictions,
            "cache_invalidations": self.cache_invalidations,
            "presence_fallbacks": self.presence_fallbacks,
            "peak_dirty_bytes": self.peak_dirty_bytes,
            "read_batches": self.read_batches,
            "read_fallback_rounds": self.read_fallback_rounds,
            "fetch_elisions": self.fetch_elisions,
        }

    def __repr__(self) -> str:  # debugging convenience
        return (
            f"ClusterStats(logical={self.logical_bytes_written}, "
            f"net={self.net_bytes}, msgs={self.control_msgs}, "
            f"lookups={self.lookup_unicasts}, ok={self.writes_ok}, "
            f"failed={self.writes_failed}, reads={self.reads_ok})"
        )


@dataclass
class DedupCluster:
    cmap: ClusterMap
    chunking: ChunkingSpec = field(default_factory=ChunkingSpec)
    nodes: dict[str, StorageNode] = field(default_factory=dict)
    transport: Transport | None = None
    stats: ClusterStats | None = None
    now: int = 0
    fault_injector: FaultInjector | None = None
    send_fingerprint_first: bool = False   # beyond-paper: lookup-before-send
    # Per-node message batching: None = auto (batched unless a fault injector
    # is listening, since the batched unicast has no between-chunk event
    # windows); True/False force it regardless of observers.
    batch_unicasts: bool | None = None
    # Cross-object unicast coalescing: one ChunkOpBatch per node for a whole
    # write_objects() batch (False reproduces the per-object message shape).
    coalesce_batches: bool = True
    # Coalesced restore: one ChunkReadBatch per target node for a whole
    # read_objects() batch, with cross-object duplicate-fetch elision
    # (False reproduces the serial per-chunk ChunkRead shape — the read
    # oracle the batched engine is proven byte-identical to).
    batch_reads: bool = True
    # At-least-once delivery: retransmissions chasing a lost message/ack
    # (0 = legacy fire-and-forget) and the simulated-ticks ack timeout per
    # attempt. None = unset: inherit the transport's settings (an injected
    # transport keeps its own, a created one uses the Transport defaults);
    # any explicit value — INCLUDING an explicit 0 / 2 — wins over an
    # injected transport's configuration. After construction both fields
    # mirror the transport's truth.
    retry_budget: int | None = None
    ack_timeout: int | None = None
    _txn_counter: int = 0
    # DedupClient sessions with a presence cache, keyed by session id —
    # the fan-out targets of PresenceInvalidate (delete/GC/reap). Sessions
    # register via ``_register_session`` (done by DedupClient itself);
    # cache-disabled sessions never register, so clusters without presence
    # caching see zero extra messages or handlers.
    _sessions: dict = field(default_factory=dict)
    _session_seq: int = 0
    _pending_inval: list = field(default_factory=list)
    _default_session: object | None = field(default=None, repr=False)
    # Fingerprints of waves that are SENT but not yet COMMITTED, keyed by
    # batch txn. Under the Scheduler a session yields between ``_wave_send``
    # and ``_wave_commit``, so a repair round can start inside that window;
    # its refcount audit would otherwise see the wave's chunk refs with no
    # committed recipe referencing them and decref live data. The registry
    # is the host's own in-flight transaction knowledge (same authority as
    # ``exclude_after``), not cross-node state. The synchronous write path
    # runs all three phases back-to-back, so it is always empty there.
    _inflight_wave_fps: dict = field(default_factory=dict, repr=False)

    def __post_init__(self) -> None:
        if self.transport is None:
            self.transport = Transport(handlers=self.nodes)
        self.transport.fault_hook = self._transport_fault
        if self.retry_budget is not None:
            self.transport.retry_budget = self.retry_budget
        if self.ack_timeout is not None:
            self.transport.ack_timeout = self.ack_timeout
        self.retry_budget = self.transport.retry_budget
        self.ack_timeout = self.transport.ack_timeout
        if self.stats is None:
            self.stats = ClusterStats(self.transport, self.nodes)

    # ------------------------------------------------------------- lifecycle
    @classmethod
    def create(
        cls,
        n_nodes: int,
        replicas: int = 1,
        chunking: ChunkingSpec | None = None,
        policy=None,
        **kw,
    ) -> "DedupCluster":
        ids = tuple(f"oss{i}" for i in range(n_nodes))
        cmap = ClusterMap(epoch=1, nodes=ids, replicas=replicas)
        c = cls(cmap=cmap, chunking=(chunking or ChunkingSpec()).normalized(), **kw)
        for nid in ids:
            c.nodes[nid] = StorageNode(nid)
            c.nodes[nid].set_cmap(cmap, 0)
        if policy is not None:
            c.transport.policy = policy
        return c

    def node(self, nid: str) -> StorageNode:
        return self.nodes[nid]

    def crash_node(self, nid: str) -> None:
        self.nodes[nid].crash()

    def restart_node(self, nid: str) -> None:
        self.nodes[nid].restart()

    def set_clock_skew(self, offsets: dict[str, int], guard: bool = True) -> int:
        """Inject bounded per-node clock skew (ROADMAP item 4): each node's
        local clock reads ``now + offsets.get(node_id, 0)``. With ``guard``
        (the default, and what a deployment that KNOWS its skew bound would
        configure) every node also widens its tombstone-reap horizon by the
        bound ``max(|offset|)``, so the fastest clock in the fleet cannot
        nominate a tombstone for reaping before its true age passes the GC
        horizon. ``guard=False`` models the unguarded deployment — the
        chaos schedule in tests/test_simclock.py shows a fast clock reaping
        early and resurrecting a deleted object without it. Returns the
        skew bound applied."""
        max_skew = max((abs(v) for v in offsets.values()), default=0)
        for nid, node in self.nodes.items():
            node.clock_offset = offsets.get(nid, 0)
            node.skew_guard = max_skew if guard else 0
        return max_skew

    def tick(self, dt: int = 1) -> None:
        """Advance simulated time; land in-flight (duplicated/reordered)
        message copies, then drain async consistency queues."""
        for _ in range(dt):
            self.now += 1
            self.transport.advance(self.now)
            for n in self.nodes.values():
                n.tick(self.now)
        self._flush_presence_invalidations()

    def run_gc(self) -> dict[str, list[Fingerprint]]:
        removed = {nid: n.run_gc(self.now) for nid, n in self.nodes.items()}
        # Each node's GC hook queued its reclaimed fps (when sessions are
        # registered); fan the invalidations out now, after every node ran.
        self._flush_presence_invalidations()
        return removed

    # -------------------------------------------------- client sessions
    def client(
        self, presence_cache: int = 0, wave_bytes: int = 0, src: str = "client"
    ):
        """Open a ``DedupClient`` session on this cluster — the public
        write/read surface (``put/put_many/get/delete/flush/close``).
        ``src`` names the session's transport endpoint: distinct names give
        concurrent sessions their own per-edge accounting (the multi-tenant
        workload opens ``c0..cN-1``); the default keeps every legacy edge
        key byte-identical."""
        from repro.core.client import DedupClient

        return DedupClient(
            self, presence_cache=presence_cache, wave_bytes=wave_bytes, src=src
        )

    def _default_client(self):
        """The cache-disabled session backing the legacy
        ``write_object``/``write_objects`` shims."""
        if self._default_session is None:
            self._default_session = self.client()
        return self._default_session

    def _register_session(self, session) -> None:
        """Register a presence-caching session as an invalidation fan-out
        target: it becomes addressable on the transport (under its session
        id) and every node's GC gains a reclaim hook feeding the
        invalidation queue."""
        if session.session_id is None:
            session.session_id = f"session{self._session_seq}"
            self._session_seq += 1
        self._sessions[session.session_id] = session
        self.transport.extra_handlers[session.session_id] = session
        self._wire_gc_hooks()

    def _unregister_session(self, session) -> None:
        self._sessions.pop(session.session_id, None)
        self.transport.extra_handlers.pop(session.session_id, None)

    def _wire_gc_hooks(self) -> None:
        for nid, n in self.nodes.items():
            if n.gc.on_reclaim is None:
                n.gc.on_reclaim = (
                    lambda fps, _nid=nid: self._queue_presence_invalidation(
                        _nid, fps
                    )
                )

    def _queue_presence_invalidation(self, nid: str, fps) -> None:
        if self._sessions and fps:
            self._pending_inval.append((nid, tuple(fps)))

    def _flush_presence_invalidations(self) -> None:
        if not self._pending_inval:
            return
        pending, self._pending_inval = self._pending_inval, []
        for nid, fps in pending:
            self._invalidate_presence(nid, fps, "gc")

    def _invalidate_presence(self, src: str, fps, reason: str) -> None:
        """Fan a ``PresenceInvalidate`` out to every registered session.
        Best-effort on purpose: a lost/partitioned invalidation leaves
        stale presence, which the receiver-side validation of presence
        ops degrades to a fallback byte resend — never a dangling ref."""
        if not self._sessions or not fps:
            return
        msg = PresenceInvalidate(tuple(fps), reason)
        for sid in list(self._sessions):
            try:
                self.transport.send(src, sid, msg, self.now)
            except (MessageDropped, NodeDown):
                pass

    # -------------------------------------------------------------- fault hook
    def _fault(self, event: str, **ctx) -> None:
        if self.fault_injector is not None:
            self.fault_injector(event, {"now": self.now, **ctx})

    def _transport_fault(self, event: str, ctx: dict) -> None:
        self._fault(event, **ctx)

    # ------------------------------------------------------------ placement
    def chunk_targets(self, fp: Fingerprint) -> list[str]:
        return place(fp, self.cmap)

    def omap_targets(self, name: str) -> list[str]:
        return place(name_fp(name), self.cmap)

    def _live(self, targets: list[str]) -> list[str]:
        return [t for t in targets if self.nodes[t].alive]

    # ----------------------------------------------------------------- write
    def write_object(self, name: str, data: bytes) -> Fingerprint:
        """Complete write transaction. Returns the object fingerprint.

        .. deprecated:: use ``DedupClient.put_many`` (``cluster.client()``)
           — the session facade is the public write surface and owns the
           write-back/presence caches. This shim delegates to a
           cache-disabled default session and keeps the legacy
           message-for-message behavior."""
        return self.write_objects([(name, data)])[0]

    def write_objects(self, items: list[tuple[str, bytes]]) -> list[Fingerprint]:
        """Batched write pipeline: semantically identical to looping
        ``write_object`` (same fingerprints, refcounts, OMAP state,
        rollback behavior and fault event points) but vectorized, coalesced
        per target node, and streamed in bounded waves — see
        ``DedupClient.put_many`` (core/client.py) for the full contract.

        .. deprecated:: use ``DedupClient.put_many`` (``cluster.client()``)
           — this shim delegates to a cache-disabled default session
           (presence cache off, unbounded waves), preserving the legacy
           message shape byte-for-byte."""
        return self._default_client().put_many(items)

    # ---------------------------------------------- coalesced batch write
    def _write_wave(self, wave: list, session=None) -> list[Fingerprint]:
        """One coalesced write wave (unique object names), synchronously:
        plan, send, commit back to back. This is the call-driven path every
        legacy caller rides; the discrete-event scheduler drives the same
        three phases through ``DedupClient.put_wave_actor`` with a yield
        between send and commit so concurrent sessions interleave — both
        paths produce the identical message sequence for a single session
        (chunking emits no messages, so deferring commit past the next
        wave's chunking changes nothing on the wire).

        Three phases — ``_wave_plan`` (per object, in order: ingress,
        idempotence/replace check, target placement, intra-batch dedup),
        ``_wave_send`` (ONE ChunkOpBatch per target node for the whole
        wave, plus the stale-presence byte-resend fallback),
        ``_wave_commit`` (per object, in order: OmapPut; rollback + raise
        at the first failure, releasing the refs of every not-yet-committed
        object so a retry of the tail reproduces the serial outcome).

        ``session`` (a ``DedupClient``) hooks the presence cache in: a
        plan-time presence hit turns a would-ship-bytes op into a
        presence-asserted ref-only op (no bytes travel, no CIT probe is
        booked — ``probe_elisions``); a receiver answering 'miss' for such
        an op (stale presence: the invalidation was lost or is still in
        flight) triggers a fallback resend of the actual bytes before the
        commit phase judges acks, so staleness degrades to the ordinary
        path instead of failing the write. Acked storing outcomes teach
        the session's presence cache. ``session=None`` (or a session with
        the cache disabled) reproduces the legacy behavior exactly.
        """
        state = self._wave_plan(wave, session)
        self._wave_send(state, session)
        return self._wave_commit(state, session)

    def _wave_plan(self, wave: list, session=None) -> dict:
        """Plan phase: per object, in order — txn allocation, ingress
        transfer, idempotence/replace check, chunk target placement,
        intra-batch first-writer dedup and presence elision. Returns the
        wave state dict threaded through ``_wave_send``/``_wave_commit``:
        ``plans``, ``planning_failure``, ``batch_txn``, ``src`` (the
        session's transport endpoint) and ``committed`` (filled at commit:
        ``(name, version)`` per committed object — the serialization
        witness the concurrent-session oracle replays)."""
        src = getattr(session, "src", "client")
        plans: list[dict] = []
        # (exc, obj size, counted in writes_failed) — a planning failure is
        # raised only after the objects planned before it have committed.
        planning_failure: tuple[Exception, int, bool] | None = None
        first_writer: set[Fingerprint] = set()

        for name, data, chunks, fps in wave:
            self._txn_counter += 1
            txn = self._txn_counter
            self.stats.logical_bytes_written += len(data)
            omap_nodes = self._live(self.omap_targets(name))
            if not omap_nodes:
                self.stats.writes_failed += 1
                planning_failure = (
                    WriteError(f"no live OMAP target for {name!r}"),
                    len(data),
                    True,
                )
                break
            primary = omap_nodes[0]
            self.transport.client_transfer(primary, len(data), src=src)
            try:
                self._fault("primary_selected", name=name, primary=primary, txn=txn)
                prev = self._omap_lookup(name, src=primary, strict=True)
            except TransactionAbort as e:
                # The serial loop re-raises planning-phase aborts uncounted;
                # earlier objects still commit before we propagate it.
                planning_failure = (e, len(data), False)
                break
            except WriteError as e:
                self.stats.writes_failed += 1
                planning_failure = (e, len(data), True)
                break
            if prev is not None:
                if prev.object_fp == object_fp(fps):
                    self.stats.writes_ok += 1
                    plans.append(
                        {"kind": "done", "name": name, "ofp": prev.object_fp,
                         "size": len(data)}
                    )
                    continue
                # Rewriting different content replaces the old object — but
                # the old refs (the fetched ``prev`` entry, kept on the
                # plan) are released at *commit* time, so an earlier
                # object's failure (which aborts this whole tail) leaves the
                # previous version intact, exactly like the serial loop that
                # never reached this item.

            ops: list[tuple[int, Fingerprint, bytes | None, list[str], bool]] = []
            failed_chunk: int | None = None
            for i, (fp, chunk) in enumerate(zip(fps, chunks)):
                live = self._live(self.chunk_targets(fp))
                if not live:
                    failed_chunk = i
                    break
                # Intra-batch dedup: the first writer of a fingerprint ships
                # bytes; every later op in the wave is ref-only (the bytes
                # are already on the same placement targets). A presence-
                # cache hit makes even the first writer ref-only — asserted
                # (presence=True) rather than known, so the receiver
                # validates and the send phase falls back on 'miss'.
                payload = None if fp in first_writer else chunk
                presence = False
                if (
                    payload is not None
                    and session is not None
                    and session.presence_hit(fp)
                ):
                    payload = None
                    presence = True
                first_writer.add(fp)
                ops.append((i, fp, payload, live, presence))
            if failed_chunk is not None:
                self.stats.writes_failed += 1
                cause = WriteError(f"chunk {failed_chunk} of {name!r}: no live target")
                exc = WriteError(f"write {name!r} failed: {cause}")
                exc.__cause__ = cause
                planning_failure = (exc, len(data), True)
                break
            plans.append(
                {
                    "kind": "write",
                    "name": name,
                    "data": data,
                    "chunks": chunks,  # kept resident for presence fallback
                    "fps": fps,
                    "ops": ops,
                    "primary": primary,
                    "txn": txn,
                    "prev": prev,  # non-None only for replaces (done short-circuits)
                    "acked": {i: [] for i, _, _, _, _ in ops},
                }
            )
        return {
            "plans": plans,
            "planning_failure": planning_failure,
            "batch_txn": self._txn_counter,
            "src": src,
            "committed": [],
        }

    def _wave_send(self, state: dict, session=None) -> None:
        """Send phase: one ChunkOpBatch per target node for the whole wave,
        then the stale-presence fallback resends. After this returns the
        wave is IN FLIGHT: every chunk op is acked (or definitively not),
        but no commit record exists yet — the window a scheduled session
        yields in while other sessions run."""
        plans = state["plans"]
        src = state["src"]
        batch_txn = state["batch_txn"]
        node_ops: dict[str, list[ChunkOp]] = {}
        node_refs: dict[str, list[tuple[int, int]]] = {}  # (plan idx, chunk idx)
        for pi, plan in enumerate(plans):
            if plan["kind"] != "write":
                continue
            primary = plan["primary"]
            for i, fp, payload, live, presence in plan["ops"]:
                op = ChunkOp(fp, payload, origin=primary, presence=presence)
                for t in live:
                    node_ops.setdefault(t, []).append(op)
                    node_refs.setdefault(t, []).append((pi, i))
        fallback: dict[str, list[tuple[int, int]]] = {}
        for t, ops in node_ops.items():
            elided = sum(1 for op in ops if op.presence)
            if elided:
                self.stats.probe_elisions += elided
            msg = ChunkOpBatch(
                ops=tuple(ops),
                txn=batch_txn,
                fp_first=self.send_fingerprint_first,
            )
            try:
                outcomes = self.transport.send(src, t, msg, self.now)
            except MessageDropped as e:
                # Nothing acked on this node — but the ops may have applied
                # ("ack lost"): a conditional cancel settles it receiver-side
                # before the commit phase fails any object with an unacked
                # chunk.
                self._cancel_unconfirmed(
                    src, t, e, fps=tuple(op.fp for op in ops)
                )
                continue
            except (NodeDown, TransactionAbort):
                # Aborted before delivery: nothing applied on this node; the
                # commit phase fails (and rolls back) any object that ends
                # up with an unacked chunk.
                continue
            for (pi, i), outcome in zip(node_refs[t], outcomes):
                if outcome != "miss":
                    plans[pi]["acked"][i].append(t)
                    if session is not None:
                        session.presence_note(plans[pi]["fps"][i])
                elif session is not None:
                    # 'miss' only happens when a presence assertion (this
                    # op's, or the elided first-writer's earlier in the same
                    # batch) was stale — queue a byte resend.
                    fallback.setdefault(t, []).append((pi, i))

        # ---- fallback: stale presence degrades to shipping the bytes ------
        for t, refs in fallback.items():
            for pi, i in refs:
                session.presence_drop(plans[pi]["fps"][i])
            ops = tuple(
                ChunkOp(
                    plans[pi]["fps"][i],
                    plans[pi]["chunks"][i],
                    origin=plans[pi]["primary"],
                )
                for pi, i in refs
            )
            self.stats.presence_fallbacks += len(ops)
            msg = ChunkOpBatch(
                ops=ops, txn=batch_txn, fp_first=self.send_fingerprint_first
            )
            try:
                outcomes = self.transport.send(src, t, msg, self.now)
            except MessageDropped as e:
                self._cancel_unconfirmed(
                    src, t, e, fps=tuple(op.fp for op in ops)
                )
                continue
            except (NodeDown, TransactionAbort):
                continue
            for (pi, i), outcome in zip(refs, outcomes):
                if outcome != "miss":
                    plans[pi]["acked"][i].append(t)
                    session.presence_note(plans[pi]["fps"][i])

        # The wave is now in flight: its chunk refs exist on the owners but
        # no commit record does. Register its fingerprints so a concurrently
        # scheduled repair round's refcount audit defers them (exactly like
        # ``exclude_after`` defers same-round writes); ``_wave_commit`` (or
        # the actor's abort path) releases the registration.
        pending = {
            fp
            for plan in plans
            if plan["kind"] == "write"
            for fp in plan["fps"]
        }
        if pending:
            self._inflight_wave_fps[batch_txn] = pending

    def release_inflight_wave(self, batch_txn: int) -> None:
        """Drop a wave's in-flight audit registration (idempotent). Called
        by ``_wave_commit`` on entry — commit runs without yield points, so
        no audit can interleave past this — and by ``put_wave_actor``'s
        abort path when a sent wave will never reach its commit."""
        self._inflight_wave_fps.pop(batch_txn, None)

    def inflight_audit_fps(self) -> set[Fingerprint]:
        """Union of fingerprints in sent-but-uncommitted waves — the set a
        refcount audit must treat as in-flight (see ``_inflight_wave_fps``)."""
        out: set[Fingerprint] = set()
        for fps in self._inflight_wave_fps.values():
            out |= fps
        return out

    def _wave_commit(self, state: dict, session=None) -> list[Fingerprint]:
        """Commit phase: per object, in order — OmapPut the commit record,
        release the refs of the version the put actually displaced, roll
        back and raise at the first failure. The displaced version comes
        from the put's RESPONSE, not the plan-time lookup: with concurrent
        sessions two replacers can both plan against the same previous
        entry, and releasing the plan-time fetch would double-release the
        refs of a version only one of them displaced. A write whose every
        replica refused the put (version gate: a concurrent committer got
        a newer version in first) is ``superseded``: its refs roll back,
        it counts in ``writes_ok`` + ``writes_superseded``, and it never
        enters ``state['committed']`` — exactly a committed write replaced
        an instant later, minus the wire traffic."""
        self.release_inflight_wave(state["batch_txn"])
        plans = state["plans"]
        planning_failure = state["planning_failure"]
        results: list[Fingerprint] = []
        failure: Exception | None = None
        for plan in plans:
            if plan["kind"] == "done":
                if failure is not None:
                    # Serial never reached this item; undo its no-op commit.
                    self.stats.writes_ok -= 1
                    self.stats.logical_bytes_written -= plan["size"]
                else:
                    results.append(plan["ofp"])
                continue
            if failure is not None:
                # An earlier object already failed: this one never commits.
                # Undo its refs and its logical accounting (a retry of the
                # tail will re-run it, exactly like the serial loop).
                self._rollback_refs(plan["primary"], plan["acked"], plan["ops"])
                self.stats.logical_bytes_written -= len(plan["data"])
                continue
            name, primary = plan["name"], plan["primary"]
            try:
                bad = next(
                    (i for i, _, _, _, _ in plan["ops"] if not plan["acked"][i]),
                    None,
                )
                if bad is not None:
                    raise WriteError(f"chunk {bad} of {name!r}: no live target")
                self._fault("before_omap", name=name, txn=plan["txn"])
                if not self.nodes[primary].alive:
                    raise NodeDown(primary)
                ofp = object_fp(plan["fps"])
                entry = OMAPEntry(
                    name, ofp, list(plan["fps"]), len(plan["data"]), plan["txn"]
                )
                wrote, applied, prev = self._commit_omap(primary, name, entry)
                if not wrote:
                    raise WriteError(f"no live OMAP target for {name!r} at commit")
            except (NodeDown, TransactionAbort, WriteError) as e:
                self._rollback_refs(primary, plan["acked"], plan["ops"])
                self.stats.writes_failed += 1
                failure = WriteError(f"write {name!r} failed: {e}")
                failure.__cause__ = e
                continue
            if not applied:
                # Every replica's version gate refused the record: a
                # concurrent session committed a newer version between our
                # plan and commit. Superseded — roll back our refs (the
                # winner's are the live ones) and report success.
                self._rollback_refs(primary, plan["acked"], plan["ops"])
                self.stats.writes_superseded += 1
                self.stats.writes_ok += 1
                results.append(ofp)
                continue
            if prev is not None and not prev.deleted:
                # Release the refs of the version THIS put displaced —
                # response-carried, so concurrent replacers each release a
                # distinct version exactly once — only now that the commit
                # record is durably written (the OmapPut overwrote the old
                # entry in place — no OmapDelete needed): a failure
                # anywhere before this leaves the previous version fully
                # intact. A displaced TOMBSTONE took no refs (the delete
                # released them). The new ops already took their refs, so
                # shared chunks dip to N, not 0.
                self._release_entry_refs(prev, src=primary)
            self.stats.writes_ok += 1
            state["committed"].append((name, plan["txn"]))
            results.append(ofp)

        if failure is not None:
            if planning_failure is not None:
                # Serial would have stopped at the commit failure, never
                # reaching the planning-failed item: undo its accounting.
                if planning_failure[2]:
                    self.stats.writes_failed -= 1
                self.stats.logical_bytes_written -= planning_failure[1]
            raise failure
        if planning_failure is not None:
            raise planning_failure[0]
        return results

    def _commit_omap(
        self, src: str, name: str, entry: OMAPEntry
    ) -> tuple[bool, bool, OMAPEntry | None]:
        """Write the commit record to every live OMAP replica. Returns
        ``(wrote, applied, prev)``: ``wrote`` — at least one replica acked
        (the transaction commits); ``applied`` — at least one replica's
        version gate accepted the record (False means a concurrent
        committer superseded this write before it landed anywhere);
        ``prev`` — the record the FIRST applying replica in placement
        order displaced (entry or tombstone, None for a fresh name). The
        first-in-placement-order choice matters: the primary is the
        authority the plan-time lookup consulted, and a lagging replica
        that missed an earlier replace would report a version whose refs
        were already released — taking the earliest live replica's answer
        keeps release exactly-once under both races and replica lag.

        When NO replica acks, any maybe-applied put is conditionally
        cancelled receiver-side so a failed transaction cannot leave a
        committed-looking entry behind — and because the OmapPut is
        idempotent and cancels are conditional, a RETRIED commit neither
        double-applies nor rolls back a replica that did commit: a replica
        that applied the first put simply re-acks it (response included:
        the same (applied, prev) tuple) from its seen-window."""
        wrote = False
        applied = False
        prev: OMAPEntry | None = None
        unconfirmed: list[tuple[str, MessageDropped]] = []
        for t in self._live(self.omap_targets(name)):
            try:
                resp = self.transport.send(src, t, OmapPut(entry), self.now)
                wrote = True
                if not applied and isinstance(resp, tuple) and resp[0]:
                    applied = True
                    prev = resp[1]
            except MessageDropped as e:
                unconfirmed.append((t, e))
        if not wrote:
            for t, e in unconfirmed:
                self._cancel_unconfirmed(src, t, e, omap_name=name)
        return wrote, applied, prev

    def _cancel_unconfirmed(
        self,
        src: str,
        dst: str,
        exc: MessageDropped,
        fps: tuple = (),
        omap_name: str | None = None,
        undelete_version: int = 0,
    ) -> None:
        """Resolve the at-least-once ambiguity after a send exhausted its
        retry budget: when ``maybe_applied`` the op may have landed without
        its ack, so a blind rollback would either miss applied refs
        ("ack lost, op applied") or double-release ("op lost"). The
        conditional ``TxnCancel`` decides AT the receiver: compensate if
        the message id is in its seen-window, otherwise poison the id so a
        copy still in flight is discarded. Best-effort — a cancel that is
        itself lost leaves at worst the legacy unreachable-node garbage."""
        if not exc.maybe_applied:
            return  # no attempt reached the receiver: nothing ever applied
        try:
            self.transport.send(
                src,
                dst,
                TxnCancel(
                    exc.msg_id,
                    tuple(fps),
                    omap_name,
                    undelete=undelete_version > 0,
                    ref_version=undelete_version,
                ),
                self.now,
            )
        except (MessageDropped, NodeDown):
            pass

    def _rollback_refs(self, src: str, acked: dict, ops) -> None:
        """Release the refcounts one failed wave object took (plan shape)."""
        self._rollback_acked(src, ((fp, acked[i]) for i, fp, _, _, _ in ops))

    def _rollback_acked(self, src: str, pairs) -> None:
        """Release acked (fp, nodes) refs, one DecrefBatch per node.
        Unreachable decrements leave flag-0 garbage for GC — the paper's
        failure model."""
        undo: dict[str, list[Fingerprint]] = {}
        for fp, on in pairs:
            for t in on:
                undo.setdefault(t, []).append(fp)
        for t, undo_fps in undo.items():
            node = self.nodes.get(t)
            if node is None or not node.alive:
                continue
            try:
                self.transport.send(src, t, DecrefBatch(tuple(undo_fps)), self.now)
            except (MessageDropped, NodeDown):
                pass

    # ------------------------------------------------- per-object write path
    def _write_prepared(
        self,
        name: str,
        data: bytes,
        chunks: list[bytes],
        fps: list[Fingerprint],
        batched: bool,
    ) -> Fingerprint:
        """One object's write transaction over pre-chunked, pre-fingerprinted
        content (paper Fig 3, steps after the primary's chunk+fingerprint)."""
        self._txn_counter += 1
        txn = self._txn_counter
        self.stats.logical_bytes_written += len(data)

        # 1. client -> primary OSS by object-name hash (full object travels).
        omap_nodes = self._live(self.omap_targets(name))
        if not omap_nodes:
            self.stats.writes_failed += 1
            raise WriteError(f"no live OMAP target for {name!r}")
        primary = omap_nodes[0]
        self.transport.client_transfer(primary, len(data))
        self._fault("primary_selected", name=name, primary=primary, txn=txn)

        # Idempotence: rewriting an identical object is a no-op; rewriting
        # different content under an existing name replaces it — but the
        # old refs are released at COMMIT time (matching the coalesced
        # wave): a failed replace leaves the previous version fully intact,
        # so a client retry releases it exactly once instead of
        # double-decrementing refs a failed first attempt already dropped.
        try:
            prev = self._omap_lookup(name, src=primary, strict=True)
        except WriteError:
            self.stats.writes_failed += 1
            raise
        if prev is not None and prev.object_fp == object_fp(fps):
            self.stats.writes_ok += 1
            return prev.object_fp

        # 2. fingerprint-routed chunk unicasts, batched per target node.
        acked: list[tuple[Fingerprint, list[str]]] = []
        try:
            if batched:
                acked, fail_idx = self._route_chunks_batched(primary, fps, chunks, txn)
                if fail_idx is not None:
                    raise WriteError(f"chunk {fail_idx} of {name!r}: no live target")
            else:
                # Chunk-granular path: a batched unicast has no window between
                # two chunk ops, so when a fault injector is listening we keep
                # per-chunk messaging to preserve every observable event point
                # (before/after_chunk_op at each index).
                for i, (fp, chunk) in enumerate(zip(fps, chunks)):
                    self._fault("before_chunk_op", name=name, index=i, fp=fp, txn=txn)
                    written_on = self._send_chunk_granular(primary, fp, chunk, txn)
                    if not written_on:
                        raise WriteError(f"chunk {i} of {name!r}: no live target")
                    acked.append((fp, written_on))
                    self._fault("after_chunk_op", name=name, index=i, fp=fp, txn=txn)

            # 3. all chunks acked -> OMAP entry on primary (+ replicas).
            self._fault("before_omap", name=name, txn=txn)
            if not self.nodes[primary].alive:
                raise NodeDown(primary)
            ofp = object_fp(fps)
            entry = OMAPEntry(name, ofp, list(fps), len(data), txn)
            wrote, applied, replaced = self._commit_omap(primary, name, entry)
            if not wrote:
                raise WriteError(f"no live OMAP target for {name!r} at commit")
        except (NodeDown, TransactionAbort, WriteError) as e:
            # Failed object transaction: best-effort rollback of the
            # refcounts we took.
            self._rollback_acked(primary, acked)
            self.stats.writes_failed += 1
            raise WriteError(f"write {name!r} failed: {e}") from e

        if not applied:
            # Superseded by a concurrent committer's newer version: roll
            # back our refs (the winner's stand) and report success — see
            # ``_wave_commit`` for the semantics.
            self._rollback_acked(primary, acked)
            self.stats.writes_superseded += 1
            self.stats.writes_ok += 1
            return ofp
        if replaced is not None and not replaced.deleted:
            # Committed (the OmapPut overwrote the old entry in place):
            # release the refs of the version this put actually displaced
            # (response-carried — race-safe under concurrent replacers),
            # exactly once. Any failure above left the previous version
            # fully intact; a displaced tombstone took no refs.
            self._release_entry_refs(replaced, src=primary)
        self.stats.writes_ok += 1
        return ofp

    def _route_chunks_batched(
        self, primary: str, fps: list[Fingerprint], chunks: list[bytes], txn: int
    ) -> tuple[list[tuple[Fingerprint, list[str]]], int | None]:
        """Group one object's chunk ops per target node -> one ChunkOpBatch
        each. Returns (acked, fail_idx); fail_idx is the first chunk with no
        live target (or, under a lossy policy, no surviving ack) and —
        matching the serial abort point — no op at or past a planning
        failure is applied."""
        targets_per_chunk: list[list[str]] = []
        fail_idx: int | None = None
        for i, fp in enumerate(fps):
            live = self._live(self.chunk_targets(fp))
            if not live:
                fail_idx = i
                break
            targets_per_chunk.append(live)

        per_node: dict[str, list[int]] = {}
        for i, live in enumerate(targets_per_chunk):
            for t in live:
                per_node.setdefault(t, []).append(i)

        acked_on: dict[int, list[str]] = {i: [] for i in range(len(targets_per_chunk))}
        for t, idxs in per_node.items():
            msg = ChunkOpBatch(
                ops=tuple(ChunkOp(fps[i], chunks[i], origin=primary) for i in idxs),
                txn=txn,
                fp_first=self.send_fingerprint_first,
            )
            try:
                outcomes = self.transport.send(primary, t, msg, self.now)
            except MessageDropped as e:
                # Unacked: settle "applied without ack?" receiver-side; the
                # ack check below decides the transaction's fate.
                self._cancel_unconfirmed(primary, t, e, fps=tuple(fps[i] for i in idxs))
                continue
            for i, outcome in zip(idxs, outcomes):
                if outcome != "miss":
                    acked_on[i].append(t)

        acked = [(fps[i], acked_on[i]) for i in range(len(targets_per_chunk)) if acked_on[i]]
        if fail_idx is None:
            lost = next((i for i in range(len(targets_per_chunk)) if not acked_on[i]), None)
            if lost is not None:
                fail_idx = lost
        return acked, fail_idx

    def _send_chunk_granular(
        self, primary: str, fp: Fingerprint, chunk: bytes, txn: int
    ) -> list[str]:
        """Route one chunk to its replica set, one single-op unicast per
        replica. Returns nodes that took a ref."""
        written_on: list[str] = []
        for t in self.chunk_targets(fp):
            if not self.nodes[t].alive:
                continue
            msg = ChunkOpBatch(
                ops=(ChunkOp(fp, chunk, origin=primary),),
                txn=txn,
                fp_first=self.send_fingerprint_first,
            )
            try:
                outcomes = self.transport.send(primary, t, msg, self.now)
            except MessageDropped as e:
                self._cancel_unconfirmed(primary, t, e, fps=(fp,))
                continue
            if outcomes[0] != "miss":
                written_on.append(t)
        return written_on

    def write_object_by_ref(self, name: str, src_name: str) -> Fingerprint | None:
        """Reference-only write: create object `name` with the same layout as
        `src_name`, incrementing chunk refcounts without moving data
        (checkpointer device-fp fast path) — one RefOnlyWrite unicast per
        target node. Fails (None) if any chunk is invalid and unrepairable,
        in which case the caller falls back to a full write."""
        src = self._omap_lookup(src_name, src="client")
        if src is None:
            return None
        per_node: dict[str, list[Fingerprint]] = {}
        for fp in src.chunk_fps:
            for t in self._live(self.chunk_targets(fp)):
                per_node.setdefault(t, []).append(fp)
        taken: dict[str, list[Fingerprint]] = {}
        holders: dict[Fingerprint, int] = {fp: 0 for fp in src.chunk_fps}
        for t, fps in per_node.items():
            try:
                results = self.transport.send(
                    "client", t, RefOnlyWrite(tuple(fps)), self.now
                )
            except MessageDropped as e:
                self._cancel_unconfirmed("client", t, e, fps=tuple(fps))
                continue
            except NodeDown:
                continue
            for fp, res in zip(fps, results):
                if res != "miss":
                    taken.setdefault(t, []).append(fp)
                    holders[fp] += 1

        def _undo() -> None:
            self._rollback_acked(
                "client", ((fp, (t,)) for t, fps in taken.items() for fp in fps)
            )

        if any(cnt == 0 for cnt in holders.values()):
            _undo()
            return None
        self._txn_counter += 1
        entry = OMAPEntry(
            name, src.object_fp, list(src.chunk_fps), src.size, self._txn_counter
        )
        wrote, applied, _replaced = self._commit_omap("client", name, entry)
        if not wrote or not applied:
            # Never acked, or superseded by a concurrent newer version:
            # the caller falls back to a full write. (A by-ref write over
            # an existing live name keeps the legacy leak-to-audit
            # behavior for the displaced refs — callers write fresh
            # checkpoint names.)
            _undo()
            return None
        self.stats.writes_ok += 1
        self.stats.logical_bytes_written += src.size
        return entry.object_fp

    # ------------------------------------------------------------------ read
    def read_object(self, name: str) -> bytes:
        """Complete read transaction for one object. Rides the coalesced
        restore engine as a one-object batch (``batch_reads=False``
        reproduces the serial per-chunk ``ChunkRead`` shape)."""
        return self.read_objects([name])[0]

    def read_objects(
        self, names: list[str], session=None, frag_out: list | None = None
    ) -> list[bytes]:
        """Coalesced batch restore — the read-side mirror of the write
        path's wave architecture. Plans the WHOLE batch of objects at once:

        1. OMAP probes grouped per primary node (same per-name replica
           fallback and message count as the serial path — only the probe
           order changes, so one node answers its run of names back to
           back);
        2. a batch-local fp->bytes first-reader cache collapses duplicate
           fingerprint references across (and within) the batch's recipes
           — a chunk shared by many objects travels the wire exactly once
           (``ClusterStats.fetch_elisions``), the read-side twin of the
           write path's first-writer cache;
        3. one ``ChunkReadBatch`` per target node carries every distinct
           fp routed there (``read_batches``);
        4. degraded reads stay batched: a reply reports per-fp hit/miss,
           and ONLY the misses are re-requested from each fp's next
           untried live replica in a follow-up wave
           (``read_fallback_rounds``); replicas exhausted raises
           ``ReadError`` — the serial path's failure surface.

        Per acked hit, ``session.presence_note`` teaches the session's
        presence cache (restored bytes are positive existence evidence —
        same currency as an acked write outcome). ``frag_out``, when given
        a list, receives one restore-fragmentation record per object:
        ``{"name", "chunks", "nodes", "max_chunks_one_node"}`` (distinct
        serving nodes touched, and the largest chunk run any single node
        served — the spread ROADMAP item 5's placement work is judged
        against). Objects come back in request order, each verified
        against its recipe's layout fingerprint."""
        if not self.batch_reads:
            return [self._read_object_serial(n) for n in names]
        src = getattr(session, "src", "client")

        # -- plan: OMAP probes grouped per (live-)primary node ------------
        by_primary: dict[str, list[int]] = {}
        for idx, name in enumerate(names):
            live = self._live(self.omap_targets(name))
            by_primary.setdefault(live[0] if live else "", []).append(idx)
        entries: list[OMAPEntry | None] = [None] * len(names)
        for primary in sorted(by_primary):
            for idx in by_primary[primary]:
                entries[idx] = self._omap_lookup(names[idx], src=src)
        for name, entry in zip(names, entries):
            if entry is None:
                raise ReadError(f"object {name!r} not found")

        # -- first-reader cache: distinct fps only, in first-appearance order
        need: list[Fingerprint] = []
        seen_fps: set[Fingerprint] = set()
        total_refs = 0
        for entry in entries:
            for fp in entry.chunk_fps:
                total_refs += 1
                if fp not in seen_fps:
                    seen_fps.add(fp)
                    need.append(fp)
        self.stats.fetch_elisions += total_refs - len(need)

        # -- fetch waves: one ChunkReadBatch per target node per wave -----
        fetched: dict[Fingerprint, bytes] = {}
        served_by: dict[Fingerprint, str] = {}
        tried: dict[Fingerprint, set[str]] = {fp: set() for fp in need}
        pending = need
        last: Exception | None = None
        first_wave = True
        while pending:
            per_node: dict[str, list[Fingerprint]] = {}
            for fp in pending:
                t = next(
                    (t for t in self._live(self.chunk_targets(fp))
                     if t not in tried[fp]),
                    None,
                )
                if t is None:
                    raise ReadError(
                        f"chunk {fp} unreadable on all replicas: {last}"
                    )
                tried[fp].add(t)
                per_node.setdefault(t, []).append(fp)
            if not first_wave:
                self.stats.read_fallback_rounds += 1
            first_wave = False
            misses: list[Fingerprint] = []
            for t in sorted(per_node):
                fps = per_node[t]
                self.stats.read_batches += 1
                try:
                    reply = self.transport.send(
                        src, t, ChunkReadBatch(tuple(fps)), self.now
                    )
                except (MessageDropped, NodeDown) as e:
                    # The whole unicast failed: every fp it carried walks
                    # on to its next replica in the follow-up wave.
                    last = e
                    misses.extend(fps)
                    continue
                for fp, data in zip(fps, reply.chunks):
                    if data is None:
                        last = ChunkMissing(t, fp)
                        misses.append(fp)
                    else:
                        fetched[fp] = data
                        served_by[fp] = t
                        if session is not None:
                            session.presence_note(fp)
            pending = misses

        # -- assemble + verify per object, in request order ---------------
        out: list[bytes] = []
        for name, entry in zip(names, entries):
            data = b"".join(fetched[fp] for fp in entry.chunk_fps)
            if object_fp(entry.chunk_fps) != entry.object_fp:
                raise ReadError(f"object {name!r}: layout fingerprint mismatch")
            self.stats.reads_ok += 1
            if frag_out is not None and entry.chunk_fps:
                per_node_counts: dict[str, int] = {}
                for fp in entry.chunk_fps:
                    t = served_by[fp]
                    per_node_counts[t] = per_node_counts.get(t, 0) + 1
                frag_out.append({
                    "name": name,
                    "chunks": len(entry.chunk_fps),
                    "nodes": len(per_node_counts),
                    "max_chunks_one_node": max(per_node_counts.values()),
                })
            out.append(data)
        return out

    def _read_object_serial(self, name: str) -> bytes:
        """The pre-batching read shape (one OMAP probe, then one serial
        ``ChunkRead`` per chunk with per-chunk replica walking) — kept as
        the oracle the batched engine is proven byte-identical to."""
        entry = self._omap_lookup(name, src="client")
        if entry is None:
            raise ReadError(f"object {name!r} not found")
        parts: list[bytes] = []
        for fp in entry.chunk_fps:
            parts.append(self._read_chunk(fp))
        data = b"".join(parts)
        if object_fp(entry.chunk_fps) != entry.object_fp:
            raise ReadError(f"object {name!r}: layout fingerprint mismatch")
        self.stats.reads_ok += 1
        return data

    def _omap_lookup(
        self, name: str, src: str = "client", strict: bool = False
    ) -> OMAPEntry | None:
        """Probe the live OMAP replicas for ``name``. With ``strict=True``
        (the write path's idempotence/replace check) a lost probe with no
        surviving answer raises instead of reporting 'absent' — assuming
        absence could skip releasing a replaced version's refs, leaking
        refcounts that GC can never reclaim."""
        lost = False
        for t in self._live(self.omap_targets(name)):
            try:
                e = self.transport.send(src, t, OmapGet(name), self.now)
            except (MessageDropped, NodeDown):
                lost = True
                continue
            if e is not None:
                # A tombstone answers the probe (the name is known-deleted,
                # no further replica need be asked) but reads as absence.
                return None if e.deleted else e
        if strict and lost:
            raise WriteError(f"OMAP lookup for {name!r} lost in transit")
        return None

    def _read_chunk(self, fp: Fingerprint) -> bytes:
        last: Exception | None = None
        for t in self._live(self.chunk_targets(fp)):
            try:
                return self.transport.send("client", t, ChunkRead(fp), self.now)
            except (ChunkMissing, MessageDropped, NodeDown) as e:
                last = e
        raise ReadError(f"chunk {fp} unreadable on all replicas: {last}")

    # ---------------------------------------------------------------- delete
    def delete_object(self, name: str, _src: str = "client") -> bool:
        """Tombstone-first delete, mirroring the write path's replace
        hardening: the versioned tombstone is committed to the OMAP
        replicas FIRST (>=1 ack, like ``_commit_omap``) and the recipe's
        chunk refs are released strictly AFTER. A mid-delete failure
        therefore leaves the name either fully readable (the commit never
        landed; a maybe-applied tombstone is conditionally undeleted
        receiver-side) or fully tombstoned with at worst leaked refcounts
        that the cluster-wide audit reclaims — never a readable recipe
        whose refs were half-released. Primary-routed like the write path,
        so a node<->node partition severs tombstone replication exactly as
        it severs commit replication; recovery then converges the
        survivors by commit version."""
        omap_nodes = self._live(self.omap_targets(name))
        if not omap_nodes:
            raise WriteError(f"no live OMAP target for {name!r}")
        primary = omap_nodes[0]
        entry = self._omap_lookup(name, src=primary)
        if entry is None:
            return False
        self._txn_counter += 1
        txn = self._txn_counter
        self._fault("before_tombstone", name=name, txn=txn)
        committed = False
        displaced: OMAPEntry | None = None
        unconfirmed: list[tuple[str, MessageDropped]] = []
        for t in omap_nodes:
            try:
                resp = self.transport.send(
                    primary, t, OmapDelete(name, txn), self.now
                )
                if displaced is None and isinstance(resp, OMAPEntry):
                    displaced = resp
                committed = True
            except MessageDropped as e:
                unconfirmed.append((t, e))
            except NodeDown:
                pass
        if not committed:
            for t, e in unconfirmed:
                self._cancel_unconfirmed(
                    primary, t, e, omap_name=name, undelete_version=txn
                )
            raise WriteError(f"delete {name!r}: no OMAP replica acked the tombstone")
        self._fault("before_delete_decref", name=name, txn=txn)
        # Release the refs of the entry the tombstone ACTUALLY displaced
        # (response-carried by the first applying replica, like the write
        # path's replace). The plan-time ``entry`` is stale the moment a
        # concurrent session replaces or deletes the name between our
        # lookup and our tombstone: a raced second delete sees prev =
        # tombstone (refs already released — release nothing), a delete
        # raced by a newer WRITE sees prev = that newer version only if
        # our tombstone out-versioned it (then its refs are exactly the
        # ones to drop). Either way: exactly-once.
        if displaced is not None and not displaced.deleted:
            self._release_entry_refs(displaced, src=primary)
            # The recipe's refs are released: cached "exists" evidence for
            # its chunks may go stale as soon as GC reclaims them —
            # invalidate now.
            self._invalidate_presence(
                primary, tuple(displaced.chunk_fps), "delete"
            )
        return True

    def _release_entry_refs(self, entry: OMAPEntry, src: str) -> None:
        """Release an entry's chunk refs, one DecrefBatch per node. The
        write path's replace passes the entry from its strict lookup here
        directly — re-probing could lose the probe under a lossy policy
        and leak the old version's refcounts forever."""
        per_node: dict[str, list[Fingerprint]] = {}
        for fp in entry.chunk_fps:
            for t in self._live(self.chunk_targets(fp)):
                per_node.setdefault(t, []).append(fp)
        for t, fps in per_node.items():
            try:
                self.transport.send(src, t, DecrefBatch(tuple(fps)), self.now)
            except (MessageDropped, NodeDown):
                pass

    # ------------------------------------------------------------- rebalance
    def set_map(self, new_map: ClusterMap) -> None:
        """Topology change + storage rebalance (paper Fig 1b).

        Content placement means we only *move* chunks; no dedup-metadata
        location rewrite happens anywhere (the paper's key win). The move
        itself is the recovery subsystem's per-node rebalance driver
        (``core/recovery.py``): CIT entries travel with their chunks
        (MigrateChunk); OMAP entries move by name hash (OmapPut with
        migrate=True). Under a lossy delivery policy a move can be lost in
        flight — replicas and the digest repair round (``scrub``) are the
        repair story, exactly as for node loss.
        """
        from repro.core.recovery import rebalance

        for nid in new_map.nodes:
            if nid not in self.nodes:
                self.nodes[nid] = StorageNode(nid)
        self.cmap = new_map
        for n in self.nodes.values():
            n.set_cmap(new_map, self.now)
        if self._sessions:
            self._wire_gc_hooks()  # nodes added by the new map
        rebalance(self)

    def add_node(self, weight: float = 1.0) -> str:
        nid = f"oss{len(self.nodes)}"
        self.set_map(self.cmap.with_node(nid, weight))
        return nid

    def remove_node(self, nid: str) -> None:
        self.set_map(self.cmap.without_node(nid))

    # -------------------------------------------------------------- recovery
    def scrub(self) -> int:
        """Re-replication repair, digest-driven (``core/recovery.py``):
        nodes exchange per-placement-group digests over the transport, only
        divergent groups are expanded, and every missing byte copy / CIT
        entry ships as a ``RepairChunk`` from a surviving holder. Returns
        byte copies restored."""
        from repro.core.recovery import repair_round

        return repair_round(self)

    def recover(self):
        """Full post-failure reconciliation round: OMAP repair ->
        digest-diff chunk repair -> cluster-wide refcount audit -> GC
        (``core/recovery.py``). This is the post-partition heal path, and
        what reclaims references leaked when a ``TxnCancel`` was itself
        lost after an applied-but-unacked op. Returns a
        ``RecoveryReport``."""
        from repro.core.recovery import run_recovery

        return run_recovery(self)

    # --------------------------------------------------------------- metrics
    def unique_bytes_stored(self) -> int:
        seen: set[Fingerprint] = set()
        total = 0
        for node in self.nodes.values():
            for fp, data in node.chunk_store.items():
                if fp not in seen:
                    seen.add(fp)
                    total += len(data)
        return total

    def physical_bytes_stored(self) -> int:
        return sum(n.stored_bytes() for n in self.nodes.values())

    def space_savings(self) -> float:
        logical = self.stats.logical_bytes_written
        if logical == 0:
            return 0.0
        return 1.0 - self.unique_bytes_stored() / logical

    def dedup_ratio(self) -> float:
        u = self.unique_bytes_stored()
        return self.stats.logical_bytes_written / u if u else 0.0

    def chunk_distribution(self) -> dict[str, int]:
        return {nid: len(n.chunk_store) for nid, n in self.nodes.items()}
