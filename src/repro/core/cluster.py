"""DedupCluster — the shared-nothing cluster with cluster-wide deduplication.

Implements the paper's complete write/read I/O transactions (Fig 3), the
fingerprint-routed chunk placement (Fig 2), storage rebalancing on topology
change (Fig 1b, made metadata-free by content placement), K-way replication,
failure injection, and byte-accurate network/disk accounting for the
benchmark models.

Transaction flow (write):
  client --(object bytes)--> primary OSS (by name hash)
  primary: chunk + fingerprint, then per chunk:
      target(s) = place(chunk_fp, map)  --(chunk bytes)--> target
      target: CIT lookup -> dedup_hit | repair | store (flag flips async)
  when all chunk acks arrive: primary writes OMAP entry -> txn complete.

A fault injector callback may crash nodes / abort between any two steps,
which is how the crash-consistency tests drive the paper's failure windows.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

from repro.core.chunking import ChunkingSpec, chunk_object
from repro.core.dmshard import OMAPEntry
from repro.core.fingerprint import (
    Fingerprint,
    fingerprint_many,
    name_fp,
    object_fp,
)
from repro.core.node import ChunkMissing, NodeDown, StorageNode
from repro.core.placement import ClusterMap, place

# fault injector signature: (event, context-dict) -> None. May raise
# TransactionAbort or call cluster.crash_node() to model failures.
FaultInjector = Callable[[str, dict], None]

CONTROL_MSG_BYTES = 64  # modeled size of a lookup/ack/refcount message


class TransactionAbort(RuntimeError):
    pass


class WriteError(RuntimeError):
    pass


class ReadError(RuntimeError):
    pass


@dataclass
class ClusterStats:
    logical_bytes_written: int = 0
    net_bytes: int = 0                 # payload bytes crossing the network
    control_msgs: int = 0              # lookup/ack/refcount unicasts
    lookup_unicasts: int = 0
    lookup_broadcasts: int = 0         # always 0 for us; used by baselines
    writes_ok: int = 0
    writes_failed: int = 0
    reads_ok: int = 0
    rebalance_bytes_moved: int = 0
    rebalance_chunks_moved: int = 0


@dataclass
class DedupCluster:
    cmap: ClusterMap
    chunking: ChunkingSpec = field(default_factory=ChunkingSpec)
    nodes: dict[str, StorageNode] = field(default_factory=dict)
    stats: ClusterStats = field(default_factory=ClusterStats)
    now: int = 0
    fault_injector: FaultInjector | None = None
    send_fingerprint_first: bool = False   # beyond-paper: lookup-before-send
    # Per-node message batching: None = auto (batched unless a fault injector
    # is listening, since the batched unicast has no between-chunk event
    # windows); True/False force it regardless of observers.
    batch_unicasts: bool | None = None
    _txn_counter: int = 0

    # ------------------------------------------------------------- lifecycle
    @classmethod
    def create(
        cls,
        n_nodes: int,
        replicas: int = 1,
        chunking: ChunkingSpec | None = None,
        **kw,
    ) -> "DedupCluster":
        ids = tuple(f"oss{i}" for i in range(n_nodes))
        cmap = ClusterMap(epoch=1, nodes=ids, replicas=replicas)
        c = cls(cmap=cmap, chunking=(chunking or ChunkingSpec()).normalized(), **kw)
        for nid in ids:
            c.nodes[nid] = StorageNode(nid)
        return c

    def node(self, nid: str) -> StorageNode:
        return self.nodes[nid]

    def crash_node(self, nid: str) -> None:
        self.nodes[nid].crash()

    def restart_node(self, nid: str) -> None:
        self.nodes[nid].restart()

    def tick(self, dt: int = 1) -> None:
        """Advance simulated time; drain async consistency queues."""
        for _ in range(dt):
            self.now += 1
            for n in self.nodes.values():
                n.tick(self.now)

    def run_gc(self) -> dict[str, list[Fingerprint]]:
        return {nid: n.run_gc(self.now) for nid, n in self.nodes.items()}

    # -------------------------------------------------------------- fault hook
    def _fault(self, event: str, **ctx) -> None:
        if self.fault_injector is not None:
            self.fault_injector(event, {"now": self.now, **ctx})

    # ------------------------------------------------------------ placement
    def chunk_targets(self, fp: Fingerprint) -> list[str]:
        return place(fp, self.cmap)

    def omap_targets(self, name: str) -> list[str]:
        return place(name_fp(name), self.cmap)

    def _live(self, targets: list[str]) -> list[str]:
        return [t for t in targets if self.nodes[t].alive]

    # ----------------------------------------------------------------- write
    def write_object(self, name: str, data: bytes) -> Fingerprint:
        """Complete write transaction. Returns the object fingerprint.
        Thin wrapper over the batched pipeline (a batch of one)."""
        return self.write_objects([(name, data)])[0]

    def write_objects(self, items: list[tuple[str, bytes]]) -> list[Fingerprint]:
        """Batched write pipeline. Semantically identical to looping
        ``write_object`` over ``items`` (same fingerprints, refcounts, OMAP
        state, rollback behavior and fault event points; on failure the
        exception propagates after earlier items committed, exactly like the
        loop) — but vectorized where the loop is serial:

        1. chunking (vectorized CDC) + fingerprinting run over the whole
           batch in one pass (``fingerprint_many``);
        2. each object's chunk ops are grouped per target node into one
           batched unicast (``StorageNode.receive_chunks``), so control
           messages scale with nodes touched, not chunks written.

        Each object remains its own transaction. ``lookup_unicasts`` counts
        fingerprint lookups carried (batch-invariant); ``control_msgs``
        counts messages, which batching reduces.
        """
        prepped: list[tuple[str, bytes, list[bytes]]] = []
        for name, data in items:
            prepped.append((name, data, chunk_object(data, self.chunking)))
        all_fps = fingerprint_many([c for _, _, chunks in prepped for c in chunks])
        out: list[Fingerprint] = []
        off = 0
        for name, data, chunks in prepped:
            fps = all_fps[off : off + len(chunks)]
            off += len(chunks)
            out.append(self._write_prepared(name, data, chunks, fps))
        return out

    def _write_prepared(
        self, name: str, data: bytes, chunks: list[bytes], fps: list[Fingerprint]
    ) -> Fingerprint:
        """One object's write transaction over pre-chunked, pre-fingerprinted
        content (paper Fig 3, steps after the primary's chunk+fingerprint)."""
        self._txn_counter += 1
        txn = self._txn_counter
        self.stats.logical_bytes_written += len(data)

        # 1. client -> primary OSS by object-name hash (full object travels).
        omap_nodes = self._live(self.omap_targets(name))
        if not omap_nodes:
            self.stats.writes_failed += 1
            raise WriteError(f"no live OMAP target for {name!r}")
        primary = omap_nodes[0]
        self.stats.net_bytes += len(data)
        self._fault("primary_selected", name=name, primary=primary, txn=txn)

        # Idempotence: rewriting an identical object is a no-op; rewriting
        # different content under an existing name replaces it (old refs
        # released first so refcounts stay exact).
        prev = self._omap_lookup(name)
        if prev is not None:
            if prev.object_fp == object_fp(fps):
                self.stats.writes_ok += 1
                return prev.object_fp
            self.delete_object(name)

        # 2. fingerprint-routed chunk unicasts, batched per target node.
        batched = (
            self.batch_unicasts
            if self.batch_unicasts is not None
            else self.fault_injector is None
        )
        acked: list[tuple[Fingerprint, list[str]]] = []
        try:
            if batched:
                acked, fail_idx = self._route_chunks_batched(primary, fps, chunks, txn)
                if fail_idx is not None:
                    raise WriteError(f"chunk {fail_idx} of {name!r}: no live target")
            else:
                # Chunk-granular path: a batched unicast has no window between
                # two chunk ops, so when a fault injector is listening we keep
                # per-chunk messaging to preserve every observable event point
                # (before/after_chunk_op at each index).
                for i, (fp, chunk) in enumerate(zip(fps, chunks)):
                    self._fault("before_chunk_op", name=name, index=i, fp=fp, txn=txn)
                    written_on = self._write_chunk(primary, fp, chunk, txn)
                    if not written_on:
                        raise WriteError(f"chunk {i} of {name!r}: no live target")
                    acked.append((fp, written_on))
                    self._fault("after_chunk_op", name=name, index=i, fp=fp, txn=txn)

            # 3. all chunks acked -> OMAP entry on primary (+ replicas).
            self._fault("before_omap", name=name, txn=txn)
            if not self.nodes[primary].alive:
                raise NodeDown(primary)
            ofp = object_fp(fps)
            entry = OMAPEntry(name=name, object_fp=ofp, chunk_fps=list(fps), size=len(data))
            wrote_omap = False
            for t in self._live(self.omap_targets(name)):
                self.nodes[t].shard.omap_put(
                    OMAPEntry(entry.name, entry.object_fp, list(entry.chunk_fps), entry.size)
                )
                wrote_omap = True
            if not wrote_omap:
                raise WriteError(f"no live OMAP target for {name!r} at commit")
        except (NodeDown, TransactionAbort, WriteError) as e:
            # Failed object transaction: best-effort rollback of refcounts we
            # took (batched per node). Unreachable decrements leave flag-0
            # garbage for GC — the paper's failure model.
            undo: dict[str, list[Fingerprint]] = {}
            for fp, on in acked:
                for t in on:
                    undo.setdefault(t, []).append(fp)
            for t, undo_fps in undo.items():
                node = self.nodes[t]
                if node.alive:
                    node.decref_chunks(undo_fps, self.now)
                    # one message per node when batching; per-op otherwise
                    self.stats.control_msgs += 1 if batched else len(undo_fps)
            self.stats.writes_failed += 1
            raise WriteError(f"write {name!r} failed: {e}") from e

        self.stats.writes_ok += 1
        return ofp

    def _route_chunks_batched(
        self, primary: str, fps: list[Fingerprint], chunks: list[bytes], txn: int
    ) -> tuple[list[tuple[Fingerprint, list[str]]], int | None]:
        """Group one object's chunk ops per target node -> one batched unicast
        each. Returns (acked, fail_idx); fail_idx is the first chunk with no
        live target, and — matching the serial abort point — no op at or past
        it is applied."""
        targets_per_chunk: list[list[str]] = []
        fail_idx: int | None = None
        for i, fp in enumerate(fps):
            live = [t for t in self.chunk_targets(fp) if self.nodes[t].alive]
            if not live:
                fail_idx = i
                break
            targets_per_chunk.append(live)

        per_node: dict[str, list[int]] = {}
        for i, live in enumerate(targets_per_chunk):
            for t in live:
                per_node.setdefault(t, []).append(i)

        for t, idxs in per_node.items():
            node = self.nodes[t]
            ops = [(fps[i], chunks[i]) for i in idxs]
            # One message carries |ops| fingerprint lookups + chunk writes.
            self.stats.lookup_unicasts += len(ops)
            self.stats.control_msgs += 1
            outcomes = node.receive_chunks(ops, self.now, txn)
            if t != primary:
                if self.send_fingerprint_first:
                    # beyond-paper: 64B fp probe first; bytes travel on miss
                    # only. A probe hit is exactly a dedup_hit outcome.
                    self.stats.net_bytes += sum(
                        len(c) for (_, c), o in zip(ops, outcomes) if o != "dedup_hit"
                    )
                else:
                    # paper-faithful: chunk bytes always travel to the target.
                    self.stats.net_bytes += sum(len(c) for _, c in ops)

        acked = list(zip(fps, targets_per_chunk))
        return acked, fail_idx

    def _write_chunk(self, primary: str, fp: Fingerprint, chunk: bytes, txn: int) -> list[str]:
        """Route one chunk to its replica set. Returns nodes that took a ref."""
        written_on: list[str] = []
        for t in self.chunk_targets(fp):
            node = self.nodes[t]
            if not node.alive:
                continue
            # fingerprint lookup is part of the same unicast (no broadcast!)
            self.stats.lookup_unicasts += 1
            self.stats.control_msgs += 1
            if self.send_fingerprint_first:
                # beyond-paper: 64B fp probe first; ship bytes only on miss.
                e = node.cit_entry(fp)
                hit = e is not None and e.is_valid()
                if not hit and t != primary:
                    self.stats.net_bytes += len(chunk)
            elif t != primary:
                # paper-faithful: chunk bytes always travel to the target.
                self.stats.net_bytes += len(chunk)
            node.receive_chunk(fp, chunk, self.now, txn)
            written_on.append(t)
        return written_on

    def write_object_by_ref(self, name: str, src_name: str) -> Fingerprint | None:
        """Reference-only write: create object `name` with the same layout as
        `src_name`, incrementing chunk refcounts without moving data
        (checkpointer device-fp fast path). Fails (None) if any chunk is
        invalid and unrepairable, in which case the caller falls back to a
        full write."""
        src = self._omap_lookup(src_name)
        if src is None:
            return None
        taken: list[tuple[Fingerprint, list[str]]] = []
        ok = True
        for fp in src.chunk_fps:
            on: list[str] = []
            for t in self._live(self.chunk_targets(fp)):
                node = self.nodes[t]
                self.stats.lookup_unicasts += 1
                self.stats.control_msgs += 1
                e = node.cit_entry(fp)
                if e is None:
                    continue
                if not e.is_valid():
                    # paper §2.4 consistency check via stat
                    if not node.has_chunk(fp):
                        continue
                    node.shard.cit_set_flag(fp, 1, self.now)
                    node.stats.repairs += 1
                node.shard.cit_addref(fp)
                on.append(t)
            if not on:
                ok = False
                break
            taken.append((fp, on))
        if not ok:
            for fp, on in taken:
                for t in on:
                    self.nodes[t].decref_chunk(fp, self.now)
            return None
        entry = OMAPEntry(name, src.object_fp, list(src.chunk_fps), src.size)
        wrote = False
        for t in self._live(self.omap_targets(name)):
            self.nodes[t].shard.omap_put(
                OMAPEntry(entry.name, entry.object_fp, list(entry.chunk_fps), entry.size)
            )
            self.stats.control_msgs += 1
            wrote = True
        if not wrote:
            for fp, on in taken:
                for t in on:
                    self.nodes[t].decref_chunk(fp, self.now)
            return None
        self.stats.writes_ok += 1
        self.stats.logical_bytes_written += src.size
        return entry.object_fp

    # ------------------------------------------------------------------ read
    def read_object(self, name: str) -> bytes:
        entry = self._omap_lookup(name)
        if entry is None:
            raise ReadError(f"object {name!r} not found")
        parts: list[bytes] = []
        for fp in entry.chunk_fps:
            parts.append(self._read_chunk(fp))
        data = b"".join(parts)
        if object_fp(entry.chunk_fps) != entry.object_fp:
            raise ReadError(f"object {name!r}: layout fingerprint mismatch")
        self.stats.reads_ok += 1
        return data

    def _omap_lookup(self, name: str) -> OMAPEntry | None:
        for t in self._live(self.omap_targets(name)):
            self.stats.control_msgs += 1
            e = self.nodes[t].shard.omap_get(name)
            if e is not None:
                return e
        return None

    def _read_chunk(self, fp: Fingerprint) -> bytes:
        last: Exception | None = None
        for t in self.chunk_targets(fp):
            node = self.nodes[t]
            if not node.alive:
                continue
            try:
                data = node.read_chunk(fp, self.now)
                self.stats.net_bytes += len(data)
                return data
            except ChunkMissing as e:
                last = e
        raise ReadError(f"chunk {fp} unreadable on all replicas: {last}")

    # ---------------------------------------------------------------- delete
    def delete_object(self, name: str) -> bool:
        entry = self._omap_lookup(name)
        if entry is None:
            return False
        for t in self._live(self.omap_targets(name)):
            self.nodes[t].shard.omap_delete(name)
            self.stats.control_msgs += 1
        for fp in entry.chunk_fps:
            for t in self._live(self.chunk_targets(fp)):
                self.nodes[t].decref_chunk(fp, self.now)
                self.stats.control_msgs += 1
        return True

    # ------------------------------------------------------------- rebalance
    def set_map(self, new_map: ClusterMap) -> None:
        """Topology change + storage rebalance (paper Fig 1b).

        Content placement means we only *move* chunks; no dedup-metadata
        location rewrite happens anywhere (the paper's key win). CIT entries
        travel with their chunks; OMAP entries move by name hash.
        """
        for nid in new_map.nodes:
            if nid not in self.nodes:
                self.nodes[nid] = StorageNode(nid)
        old = self.cmap
        self.cmap = new_map

        for nid, node in list(self.nodes.items()):
            if not node.alive:
                continue
            # --- migrate chunks + their CIT entries --------------------------
            for fp in list(node.chunk_store.keys()):
                targets = place(fp, new_map)
                if nid in targets:
                    continue
                data = node.chunk_store.pop(fp)
                entry = node.shard.cit_lookup(fp)
                if entry is not None:
                    node.shard.cit_remove(fp)
                moved = False
                for t in self._live(targets):
                    dst = self.nodes[t]
                    if fp not in dst.chunk_store:
                        dst.chunk_store[fp] = data
                        dst.stats.disk_bytes_written += len(data)
                        self.stats.net_bytes += len(data)
                        moved = True
                    if entry is not None and dst.shard.cit_lookup(fp) is None:
                        ne = dst.shard.cit_insert(fp, entry.size, self.now)
                        ne.refcount = entry.refcount
                        ne.flag = entry.flag
                        ne.invalid_since = entry.invalid_since
                if moved:
                    self.stats.rebalance_chunks_moved += 1
                    self.stats.rebalance_bytes_moved += len(data)
            # --- stray CIT entries without local bytes (tombstones) ---------
            for fp in list(node.shard.cit.keys()):
                targets = place(fp, new_map)
                if nid in targets:
                    continue
                entry = node.shard.cit_lookup(fp)
                node.shard.cit_remove(fp)
                for t in self._live(targets):
                    dst = self.nodes[t]
                    if dst.shard.cit_lookup(fp) is None and entry is not None:
                        ne = dst.shard.cit_insert(fp, entry.size, self.now)
                        ne.refcount = entry.refcount
                        ne.flag = entry.flag
                        ne.invalid_since = entry.invalid_since
            # --- migrate OMAP entries by object-name hash --------------------
            for name in list(node.shard.omap.keys()):
                targets = place(name_fp(name), new_map)
                if nid in targets:
                    continue
                e = node.shard.omap_delete(name)
                assert e is not None
                for t in self._live(targets):
                    self.nodes[t].shard.omap_put(
                        OMAPEntry(e.name, e.object_fp, list(e.chunk_fps), e.size)
                    )
                    self.stats.net_bytes += CONTROL_MSG_BYTES
        _ = old

    def add_node(self, weight: float = 1.0) -> str:
        nid = f"oss{len(self.nodes)}"
        self.set_map(self.cmap.with_node(nid, weight))
        return nid

    def remove_node(self, nid: str) -> None:
        self.set_map(self.cmap.without_node(nid))

    def scrub(self) -> int:
        """Re-replication repair: ensure every chunk is on all live targets.
        Returns number of chunk copies restored."""
        restored = 0
        holders: dict[Fingerprint, list[str]] = {}
        for nid, node in self.nodes.items():
            if not node.alive:
                continue
            for fp in node.chunk_store:
                holders.setdefault(fp, []).append(nid)
        for fp, have in holders.items():
            src = self.nodes[have[0]]
            entry = src.shard.cit_lookup(fp)
            for t in self._live(self.chunk_targets(fp)):
                dst = self.nodes[t]
                if fp in dst.chunk_store:
                    continue
                dst.chunk_store[fp] = src.chunk_store[fp]
                dst.stats.disk_bytes_written += len(src.chunk_store[fp])
                self.stats.net_bytes += len(src.chunk_store[fp])
                if dst.shard.cit_lookup(fp) is None and entry is not None:
                    ne = dst.shard.cit_insert(fp, entry.size, self.now)
                    ne.refcount = entry.refcount
                    ne.flag = entry.flag
                restored += 1
        return restored

    # --------------------------------------------------------------- metrics
    def unique_bytes_stored(self) -> int:
        seen: set[Fingerprint] = set()
        total = 0
        for node in self.nodes.values():
            for fp, data in node.chunk_store.items():
                if fp not in seen:
                    seen.add(fp)
                    total += len(data)
        return total

    def physical_bytes_stored(self) -> int:
        return sum(n.stored_bytes() for n in self.nodes.values())

    def space_savings(self) -> float:
        logical = self.stats.logical_bytes_written
        if logical == 0:
            return 0.0
        return 1.0 - self.unique_bytes_stored() / logical

    def dedup_ratio(self) -> float:
        u = self.unique_bytes_stored()
        return self.stats.logical_bytes_written / u if u else 0.0

    def chunk_distribution(self) -> dict[str, int]:
        return {nid: len(n.chunk_store) for nid, n in self.nodes.items()}
