"""Garbage collector (paper §2.4, last paragraph).

Periodically collects chunk fingerprints whose CIT commit flag is INVALID,
holds them for a pre-defined aging threshold, then *cross-matches* the held
set against the live CIT: any fingerprint whose entry changed in the meantime
(flag flipped valid, refcount grew, entry re-inserted) is spared; unchanged
ones are removed together with their stored chunk bytes.

No journal, no extra logging — the commit flag IS the garbage marker.

The collector also owns the OMAP delete-tombstone GC horizon
(``tombstone_horizon``): how long a tombstone must age before this node
lists it as a reap candidate in omap digest replies. Reaping itself is a
cluster decision — the recovery coordinator sends ``TombstoneReap`` only
once EVERY live placement target has listed the tombstone as aged (fully
acked), because a tombstone's whole job is to outlive any stale live
replica it still needs to beat. The horizon is therefore the maximum
replica lag the delete path tolerates: a node that rejoins after being
down longer than the horizon may resurrect a reaped name — the standard
anti-entropy tombstone trade-off, sized here at several times the chunk
aging threshold.

Tombstone aging is the one GC decision made against a *wall clock*
(``deleted_at``), so it is the one place clock skew bites: a node whose
clock runs fast nominates early, and under the wrong failure schedule
that reaps before the true horizon (tests/test_simclock.py). Nodes with
a configured skew bound (``StorageNode.skew_guard``, set by
``DedupCluster.set_clock_skew``) widen their nomination threshold to
``tombstone_horizon + skew_guard`` — see docs/concurrency.md. Under the
discrete-event Scheduler (core/simclock.py) GC runs as a recurring
actor interleaved with live client sessions.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

from repro.core.dmshard import DMShard, INVALID, VALID
from repro.core.fingerprint import Fingerprint


@dataclass(frozen=True)
class _Held:
    fp: Fingerprint
    observed_at: int
    observed_refcount: int


@dataclass
class GarbageCollector:
    threshold: int = 10            # sim-ticks a fingerprint must stay invalid
    tombstone_horizon: int = 30    # sim-ticks an OMAP delete tombstone must age
    held: dict[Fingerprint, _Held] = field(default_factory=dict)
    collected_chunks: int = 0
    collected_bytes: int = 0
    spared: int = 0
    repaired: int = 0
    audit_fed: int = 0             # entries fed pre-aged by a refcount audit
    # Reclaim hook: called with the fingerprints a run physically removed.
    # The cluster wires this (only while presence-caching client sessions
    # are registered) to queue PresenceInvalidate fan-outs — a reclaimed
    # chunk is the one event that turns cached "exists" evidence into a
    # would-be dangling reference, so it must reach the caches. Unset (the
    # default) costs nothing and changes nothing.
    on_reclaim: Callable[[list[Fingerprint]], None] | None = None

    def scan(self, shard: DMShard, now: int) -> None:
        """Phase 1: collect currently-invalid fingerprints into the held set."""
        for fp in shard.invalid_fps():
            if fp not in self.held:
                e = shard.cit_lookup(fp)
                assert e is not None
                self.held[fp] = _Held(fp, now, e.refcount)

    def note_audit(self, shard: DMShard, fp: Fingerprint, now: int) -> None:
        """Feed an audit result into the aging cross-match: the cluster-wide
        refcount audit PROVED ``fp`` unreferenced by any OMAP recipe, which
        is exactly the evidence the aging threshold normally waits to
        accumulate — so the entry enters the held set pre-aged and the next
        sweep may collect it immediately. The cross-match itself still
        applies: any refcount/flag change between the audit's observation
        and the sweep (a racing re-reference) spares the entry."""
        e = shard.cit_lookup(fp)
        if e is None or e.flag != INVALID:
            return
        self.held[fp] = _Held(fp, now - self.threshold, e.refcount)
        self.audit_fed += 1

    def sweep(self, shard: DMShard, chunk_store: dict[Fingerprint, bytes], now: int) -> list[Fingerprint]:
        """Phase 2: cross-match aged fingerprints; delete the unchanged ones.

        Returns the list of removed fingerprints.
        """
        removed: list[Fingerprint] = []
        for fp, h in list(self.held.items()):
            if now - h.observed_at < self.threshold:
                continue
            del self.held[fp]
            e = shard.cit_lookup(fp)
            if e is None:
                continue  # already gone
            # Cross-match: any sign of life since observation spares it.
            if e.flag != INVALID or e.refcount != h.observed_refcount:
                self.spared += 1
                continue
            if e.refcount > 0:
                # Referenced but still flag-invalid: this happens when the
                # async flip was lost to a crash AFTER the transaction
                # committed. Deleting would lose live data (race found by
                # tests/test_property_dedup.py). Run the paper's
                # consistency check instead: bytes present -> repair flag.
                if fp in chunk_store:
                    shard.cit_set_flag(fp, VALID, now)
                self.repaired += fp in chunk_store
                self.spared += 1
                continue
            # Unreferenced invalid entry past threshold => garbage.
            self.collected_chunks += 1
            self.collected_bytes += e.size
            shard.cit_remove(fp)
            chunk_store.pop(fp, None)
            removed.append(fp)
        return removed

    def run(self, shard: DMShard, chunk_store: dict[Fingerprint, bytes], now: int) -> list[Fingerprint]:
        self.scan(shard, now)
        removed = self.sweep(shard, chunk_store, now)
        if removed and self.on_reclaim is not None:
            self.on_reclaim(removed)
        return removed
