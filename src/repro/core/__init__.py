"""Cluster-wide deduplication for shared-nothing storage — the paper's core.

Public API:
    DedupCluster.create(n_nodes, replicas=..., chunking=...)
    cluster.client(presence_cache=..., wave_bytes=...) -> DedupClient
    client.put / put_many / get / get_many / delete / flush / close
    cluster.write_object / write_objects  (deprecated shims over a default
        cache-disabled client session) / read_object / read_objects /
        delete_object
    cluster.add_node / remove_node / scrub / run_gc / tick
    ClusterMap, ChunkSpec, ChunkingSpec, Fingerprint, fingerprint_many
"""

from repro.core.chunking import ChunkSpec, ChunkingSpec, chunk_object, window_hashes
from repro.core.client import DedupClient
from repro.core.cluster import (
    DedupCluster,
    ReadError,
    TransactionAbort,
    WriteError,
)
from repro.core.write_cache import (
    PRESENCE_OUTCOMES,
    PendingWrites,
    PresenceCache,
    WriteBackCache,
)
from repro.core.baselines import (
    CentralDedupCluster,
    DiskLocalDedupCluster,
    NoDedupCluster,
    UnsupportedTransportPolicy,
)
from repro.core.dmshard import CITEntry, DMShard, INVALID, OMAPEntry, VALID
from repro.core.messages import (
    ACK_MSG_BYTES,
    CONTROL_MSG_BYTES,
    DIGEST_ENTRY_BYTES,
    DIGEST_GROUP_BYTES,
    OMAP_DIGEST_ENTRY_BYTES,
    RECIPE_REF_BYTES,
    TOMBSTONE_RECORD_BYTES,
    ChunkOp,
    ChunkOpBatch,
    ChunkRead,
    ChunkReadBatch,
    ChunkReadBatchReply,
    DecrefBatch,
    DigestReply,
    DigestRequest,
    Message,
    MigrateChunk,
    OmapDelete,
    OmapGet,
    OmapPut,
    PRESENCE_FP_BYTES,
    PresenceInvalidate,
    RawPut,
    RefAudit,
    RefOnlyWrite,
    RepairChunk,
    TombstoneReap,
    TxnCancel,
)
from repro.core.node import DirtyTracker, StorageNode
from repro.core.recovery import (
    RecoveryReport,
    RecoveryRound,
    RepairDaemon,
    repair_round,
    run_recovery,
)
from repro.core.transport import (
    Envelope,
    MessageDropped,
    SeenWindow,
    Transport,
    ack_loss,
    chaos,
    delay,
    drop,
    duplicate,
    partition,
    reliable,
    reorder,
)
from repro.core.fingerprint import (
    Fingerprint,
    chain_fp,
    fingerprint_many,
    name_fp,
    object_fp,
    sha256_fp,
)
from repro.core.placement import ClusterMap, place, primary
from repro.core.simclock import Scheduler, SimClock
from repro.core.workload import ClientRecord, WorkloadOp, WorkloadSpec, run_workload

__all__ = [
    "ChunkSpec",
    "ChunkingSpec",
    "chunk_object",
    "window_hashes",
    "fingerprint_many",
    "DedupClient",
    "DedupCluster",
    "PRESENCE_OUTCOMES",
    "PendingWrites",
    "PresenceCache",
    "WriteBackCache",
    "CentralDedupCluster",
    "DiskLocalDedupCluster",
    "NoDedupCluster",
    "UnsupportedTransportPolicy",
    "ReadError",
    "TransactionAbort",
    "WriteError",
    "CITEntry",
    "DMShard",
    "INVALID",
    "VALID",
    "OMAPEntry",
    "Fingerprint",
    "chain_fp",
    "name_fp",
    "object_fp",
    "sha256_fp",
    "ClusterMap",
    "place",
    "primary",
    "ACK_MSG_BYTES",
    "CONTROL_MSG_BYTES",
    "DIGEST_ENTRY_BYTES",
    "DIGEST_GROUP_BYTES",
    "OMAP_DIGEST_ENTRY_BYTES",
    "RECIPE_REF_BYTES",
    "TOMBSTONE_RECORD_BYTES",
    "Message",
    "ChunkOp",
    "ChunkOpBatch",
    "ChunkRead",
    "ChunkReadBatch",
    "ChunkReadBatchReply",
    "DecrefBatch",
    "DigestReply",
    "DigestRequest",
    "MigrateChunk",
    "OmapDelete",
    "OmapGet",
    "OmapPut",
    "PRESENCE_FP_BYTES",
    "PresenceInvalidate",
    "RawPut",
    "RefAudit",
    "RefOnlyWrite",
    "RepairChunk",
    "TombstoneReap",
    "TxnCancel",
    "DirtyTracker",
    "StorageNode",
    "RecoveryReport",
    "RecoveryRound",
    "RepairDaemon",
    "repair_round",
    "run_recovery",
    "Transport",
    "Envelope",
    "SeenWindow",
    "MessageDropped",
    "reliable",
    "drop",
    "delay",
    "partition",
    "duplicate",
    "reorder",
    "ack_loss",
    "chaos",
    "Scheduler",
    "SimClock",
    "ClientRecord",
    "WorkloadOp",
    "WorkloadSpec",
    "run_workload",
]
