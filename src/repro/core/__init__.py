"""Cluster-wide deduplication for shared-nothing storage — the paper's core.

Public API:
    DedupCluster.create(n_nodes, replicas=..., chunking=...)
    cluster.write_object / write_objects / read_object / delete_object
    cluster.add_node / remove_node / scrub / run_gc / tick
    ClusterMap, ChunkingSpec, Fingerprint, fingerprint_many
"""

from repro.core.chunking import ChunkingSpec, chunk_object, window_hashes
from repro.core.cluster import (
    DedupCluster,
    ReadError,
    TransactionAbort,
    WriteError,
)
from repro.core.baselines import (
    CentralDedupCluster,
    DiskLocalDedupCluster,
    NoDedupCluster,
)
from repro.core.dmshard import CITEntry, DMShard, INVALID, OMAPEntry, VALID
from repro.core.messages import (
    CONTROL_MSG_BYTES,
    ChunkOp,
    ChunkOpBatch,
    ChunkRead,
    DecrefBatch,
    Message,
    MigrateChunk,
    OmapDelete,
    OmapGet,
    OmapPut,
    RawPut,
    RefOnlyWrite,
)
from repro.core.transport import (
    MessageDropped,
    Transport,
    delay,
    drop,
    partition,
    reliable,
)
from repro.core.fingerprint import (
    Fingerprint,
    chain_fp,
    fingerprint_many,
    name_fp,
    object_fp,
    sha256_fp,
)
from repro.core.placement import ClusterMap, place, primary

__all__ = [
    "ChunkingSpec",
    "chunk_object",
    "window_hashes",
    "fingerprint_many",
    "DedupCluster",
    "CentralDedupCluster",
    "DiskLocalDedupCluster",
    "NoDedupCluster",
    "ReadError",
    "TransactionAbort",
    "WriteError",
    "CITEntry",
    "DMShard",
    "INVALID",
    "VALID",
    "OMAPEntry",
    "Fingerprint",
    "chain_fp",
    "name_fp",
    "object_fp",
    "sha256_fp",
    "ClusterMap",
    "place",
    "primary",
    "CONTROL_MSG_BYTES",
    "Message",
    "ChunkOp",
    "ChunkOpBatch",
    "ChunkRead",
    "DecrefBatch",
    "MigrateChunk",
    "OmapDelete",
    "OmapGet",
    "OmapPut",
    "RawPut",
    "RefOnlyWrite",
    "Transport",
    "MessageDropped",
    "reliable",
    "drop",
    "delay",
    "partition",
]
