"""Cluster-wide deduplication for shared-nothing storage — the paper's core.

Public API:
    DedupCluster.create(n_nodes, replicas=..., chunking=...)
    cluster.write_object / write_objects / read_object / delete_object
    cluster.add_node / remove_node / scrub / run_gc / tick
    ClusterMap, ChunkingSpec, Fingerprint, fingerprint_many
"""

from repro.core.chunking import ChunkingSpec, chunk_object, window_hashes
from repro.core.cluster import (
    DedupCluster,
    ReadError,
    TransactionAbort,
    WriteError,
)
from repro.core.baselines import (
    CentralDedupCluster,
    DiskLocalDedupCluster,
    NoDedupCluster,
    UnsupportedTransportPolicy,
)
from repro.core.dmshard import CITEntry, DMShard, INVALID, OMAPEntry, VALID
from repro.core.messages import (
    ACK_MSG_BYTES,
    CONTROL_MSG_BYTES,
    ChunkOp,
    ChunkOpBatch,
    ChunkRead,
    DecrefBatch,
    Message,
    MigrateChunk,
    OmapDelete,
    OmapGet,
    OmapPut,
    RawPut,
    RefOnlyWrite,
    TxnCancel,
)
from repro.core.transport import (
    Envelope,
    MessageDropped,
    SeenWindow,
    Transport,
    ack_loss,
    chaos,
    delay,
    drop,
    duplicate,
    partition,
    reliable,
    reorder,
)
from repro.core.fingerprint import (
    Fingerprint,
    chain_fp,
    fingerprint_many,
    name_fp,
    object_fp,
    sha256_fp,
)
from repro.core.placement import ClusterMap, place, primary

__all__ = [
    "ChunkingSpec",
    "chunk_object",
    "window_hashes",
    "fingerprint_many",
    "DedupCluster",
    "CentralDedupCluster",
    "DiskLocalDedupCluster",
    "NoDedupCluster",
    "UnsupportedTransportPolicy",
    "ReadError",
    "TransactionAbort",
    "WriteError",
    "CITEntry",
    "DMShard",
    "INVALID",
    "VALID",
    "OMAPEntry",
    "Fingerprint",
    "chain_fp",
    "name_fp",
    "object_fp",
    "sha256_fp",
    "ClusterMap",
    "place",
    "primary",
    "ACK_MSG_BYTES",
    "CONTROL_MSG_BYTES",
    "Message",
    "ChunkOp",
    "ChunkOpBatch",
    "ChunkRead",
    "DecrefBatch",
    "MigrateChunk",
    "OmapDelete",
    "OmapGet",
    "OmapPut",
    "RawPut",
    "RefOnlyWrite",
    "TxnCancel",
    "Transport",
    "Envelope",
    "SeenWindow",
    "MessageDropped",
    "reliable",
    "drop",
    "delay",
    "partition",
    "duplicate",
    "reorder",
    "ack_loss",
    "chaos",
]
