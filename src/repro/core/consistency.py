"""Asynchronous tagged-consistency manager (paper §2.4).

Every incoming write I/O *registers* with the per-node consistency manager.
Once the data I/O completes, the manager flips the CIT commit flag
INVALID -> VALID **asynchronously** — no transaction lock, no journal.

Determinism adaptation (DESIGN.md §6.1): instead of a daemon thread, pending
flips live in an explicit queue with a due-time; the cluster's ``tick()``
drains due events on *alive* nodes. A node crash discards the queue — exactly
the window the paper's design tolerates: the chunk bytes are on disk but the
flag never flips, so the chunk either ages into garbage (GC) or is repaired by
the consistency check on the next duplicate write / read.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.dmshard import DMShard, VALID
from repro.core.fingerprint import Fingerprint
from repro.core.transport import BoundedIdSet


@dataclass(frozen=True)
class PendingFlip:
    fp: Fingerprint
    due: int            # sim time at which the flip may be applied
    txn_id: int         # transaction that registered the write


@dataclass
class ConsistencyManager:
    """Volatile (lost on crash) per-node flag-flip queue."""

    async_delay: int = 1           # sim-ticks between data-I/O done and flip
    queue: list[PendingFlip] = field(default_factory=list)
    flips_applied: int = 0
    flips_lost_to_crash: int = 0
    flips_coalesced: int = 0       # duplicate due-flips merged per drain pass
    flips_deduped: int = 0         # registrations refused: message id already seen
    flips_purged: int = 0          # queued flips dropped by a refcount audit
    # At-least-once guard: message ids whose flips were already registered.
    # The node's seen-window suppresses duplicate deliveries before they
    # reach us; this bounded window is the flip queue's own belt-and-braces
    # (ids are cheap, so it can outlive the node window). Volatile like the
    # queue itself — after a crash both the flips and the guard are gone,
    # which is exactly the window the tagged-consistency design tolerates.
    _seen_msg_ids: "BoundedIdSet" = field(
        default_factory=lambda: BoundedIdSet(capacity=4096)
    )

    def register(self, fp: Fingerprint, now: int, txn_id: int) -> None:
        self.register_many((fp,), now, txn_id)

    def register_many(self, fps, now: int, txn_id: int, msg_id: int | None = None) -> None:
        """Register one transaction's worth of writes in a single call —
        a batched unicast registers its whole op list at once instead of
        queueing flips one by one. A ``msg_id`` that was already registered
        (retransmitted/duplicated unicast) is a no-op: the flips for that
        delivery are queued at most once."""
        if msg_id is not None:
            if msg_id in self._seen_msg_ids:
                self.flips_deduped += 1
                return
            self._seen_msg_ids.add(msg_id)
        due = now + self.async_delay
        self.queue.extend(PendingFlip(fp, due, txn_id) for fp in fps)

    def drain(self, shard: DMShard, now: int, on_flip=None) -> int:
        """Apply all due flips, coalesced into one shard pass: duplicate
        fingerprints registered by several writes flip once. Returns the
        number of flips applied. ``on_flip(fp)`` is invoked per applied
        flip — the node hooks it to bump the fingerprint's placement-group
        dirty epoch, so an always-on incremental repair round that starts
        between a write and its async flip sees the group as still
        settling instead of silently clean."""
        due = [p for p in self.queue if p.due <= now]
        self.queue = [p for p in self.queue if p.due > now]
        seen: set[Fingerprint] = set()
        n = 0
        for p in due:
            if p.fp in seen:
                self.flips_coalesced += 1
                continue
            seen.add(p.fp)
            e = shard.cit_lookup(p.fp)
            if e is None:
                continue  # entry GCed/removed before the flip landed
            if e.refcount == 0:
                # The registering transaction aborted and rolled its
                # reference back — "I/O transaction completes" never
                # happened for this write, so the flag must stay INVALID
                # and the chunk ages into garbage.
                continue
            shard.cit_set_flag(p.fp, VALID, now)
            if on_flip is not None:
                on_flip(p.fp)
            n += 1
        self.flips_applied += n
        return n

    def purge(self, fps) -> int:
        """Drop queued flips for fingerprints a refcount audit just proved
        unreferenced (belt-and-braces: ``drain`` already refuses to flip a
        refcount-0 entry, but the audit KNOWS these flips belong to a
        leaked/rolled-back transaction, so they should not linger and fire
        against a later re-insert of the same fingerprint). Returns the
        number of flips dropped."""
        doomed = set(fps)
        before = len(self.queue)
        self.queue = [p for p in self.queue if p.fp not in doomed]
        dropped = before - len(self.queue)
        self.flips_purged += dropped
        return dropped

    def crash(self) -> None:
        self.flips_lost_to_crash += len(self.queue)
        self.queue.clear()
        self._seen_msg_ids.clear()

    def pending(self) -> int:
        return len(self.queue)

    def next_due(self) -> int | None:
        """Earliest due-time among queued flips (None when idle) — the
        scheduler's drain probe: run-to-quiescence keeps ticking until
        every node's flip queue is empty, so 'quiet' means the flags are
        settled, not merely that no actor is runnable."""
        if not self.queue:
            return None
        return min(p.due for p in self.queue)
