"""Explicit message-passing transport for the shared-nothing cluster.

The paper's claims — no central metadata bottleneck, fingerprint-routed
unicasts instead of broadcasts, flag-based asynchronous consistency — are
statements about *messages between nodes*. This module makes those messages
first-class: every cluster interaction goes through ``Transport.send``,
which owns

* delivery (dispatch to the destination's ``handle(msg, recv_time, env)``),
* per-edge and per-type byte/message accounting (``EdgeStats``), and
* the message-level fault surface: pluggable delivery policies
  (``reliable`` / ``drop`` / ``delay`` / ``partition`` / ``duplicate`` /
  ``reorder`` / ``ack_loss`` / ``chaos``) plus a hook that feeds the
  cluster's fault injector a ``transport_send`` event point.

At-least-once delivery model
----------------------------

Every unicast is stamped with a cluster-unique message id and a per-edge
sequence number (``Envelope``). The receiver acks each delivery — acks cost
``ACK_MSG_BYTES`` on the reverse edge and are part of ``net_bytes`` — and
the sender runs a simulated-clock timeout/retransmission loop:

* an attempt whose message (or whose ack) is lost costs ``ack_timeout``
  simulated ticks of waiting, then the SAME envelope is retransmitted
  (``retry_budget`` times at most);
* a retransmission of a message the receiver already applied is answered
  from the receiver's bounded seen-window (idempotent re-ack) — state is
  mutated at most once per message id;
* when the budget is exhausted ``MessageDropped`` is raised carrying the
  message id and ``maybe_applied`` — True when at least one attempt reached
  the receiver (its ack was lost, or it is still in flight), which is the
  "ack lost, op applied?" ambiguity senders must reconcile (the cluster
  answers it with a conditional ``TxnCancel``).

``retry_budget=0`` (the default) preserves the legacy fire-and-forget
model: the first lost message raises immediately.

Failure semantics (deterministic, simulation-friendly):

* **drop** loses the attempt in flight — with no retry budget the sender
  sees ``MessageDropped`` at once.
* **delay** delivers immediately in simulation order but time-shifts the
  *receive timestamp* by the configured ticks. Everything the destination
  stamps with its receive time shifts with it — most visibly the async
  commit-flag flips, which become due later, so a read racing a delayed
  write exercises the paper's repair-on-read consistency check.
* **partition** drops every message between nodes in different groups
  (the external client reaches all nodes).
* **duplicate** delivers the message normally AND enqueues a second copy
  that arrives later, after subsequent traffic (a duplicated, reordered
  arrival the receiver must suppress).
* **reorder** holds the original copy back (it arrives after later
  traffic); the sender times out and retransmits, so the late original
  lands as a stale duplicate.
* **ack_drop** delivers and applies the message but loses the ack: the
  sender cannot distinguish it from a lost message and retransmits.

Held (duplicated/reordered) copies are flushed after each subsequent
``send`` and from ``Transport.advance`` (called by the cluster's tick), so
no copy is stranded in flight forever.
"""

from __future__ import annotations

import random
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Mapping

from repro.core.messages import ACK_MSG_BYTES, CONTROL_MSG_BYTES, Message

# policy(src, dst, msg, now) -> (action, ticks) with action one of
# "deliver" | "delay" | "drop" | "dup" | "reorder" | "ack_drop".
DeliveryPolicy = Callable[[str, str, Message, int], tuple[str, int]]


class MessageDropped(RuntimeError):
    def __init__(
        self,
        src: str,
        dst: str,
        msg: Message,
        msg_id: int = 0,
        maybe_applied: bool = False,
    ):
        state = "maybe-applied" if maybe_applied else "lost"
        super().__init__(f"{msg.TYPE} {src}->{dst} dropped ({state})")
        self.src, self.dst, self.msg = src, dst, msg
        self.msg_id = msg_id
        # True when at least one attempt reached (or will reach) the
        # receiver but its ack never came back: the op may have applied.
        self.maybe_applied = maybe_applied


@dataclass(frozen=True)
class Envelope:
    """Delivery metadata stamped on every unicast: the cluster-unique
    message id (retransmissions REUSE it — receiver dedup keys on it) and
    the per-(src, dst)-edge sequence number (reorder detection)."""

    msg_id: int
    seq: int
    src: str
    dst: str
    attempt: int = 0  # 0 = original transmission, >0 = retransmission


class SeenWindow:
    """Bounded per-receiver duplicate-suppression window: message id ->
    cached response of the first application. Retransmitted or duplicated
    deliveries of a seen id are answered from the cache without touching
    state. Bounded FIFO memory: ids older than ``capacity`` messages are
    evicted — the at-least-once guarantee holds for duplicates arriving
    within the window (sized far above the in-flight message count)."""

    _ABSENT = object()

    def __init__(self, capacity: int = 1024):
        self.capacity = capacity
        # Eviction pressure (ROADMAP's seen-window sizing study): ids pushed
        # out by the bound, and the peak occupancy. At default sizing both
        # should read zero pressure — anything else means in-flight depth is
        # approaching the point where a late duplicate could slip past the
        # window and re-apply.
        self.evictions = 0
        self.high_water = 0
        self._responses: dict[int, object] = {}
        self._order: deque[int] = deque()

    def __len__(self) -> int:
        return len(self._order)

    def __contains__(self, msg_id: int) -> bool:
        return msg_id in self._responses

    def get(self, msg_id: int):
        """Cached response for ``msg_id``, or ``SeenWindow.ABSENT``."""
        return self._responses.get(msg_id, self._ABSENT)

    @property
    def ABSENT(self):
        return self._ABSENT

    def record(self, msg_id: int, response) -> int:
        """Record ``msg_id``'s first response; returns the number of older
        ids the bound evicted to make room (eviction pressure)."""
        if msg_id in self._responses:
            self._responses[msg_id] = response
            return 0
        self._order.append(msg_id)
        self._responses[msg_id] = response
        evicted = 0
        while len(self._order) > self.capacity:
            self._responses.pop(self._order.popleft(), None)
            evicted += 1
        self.evictions += evicted
        self.high_water = max(self.high_water, len(self._order))
        return evicted


class BoundedIdSet:
    """Bounded FIFO membership set for message ids (the membership-only
    sibling of ``SeenWindow``): the node's poison list and the consistency
    manager's flip-registration guard. O(1) add/contains/evict."""

    def __init__(self, capacity: int = 1024):
        self.capacity = capacity
        self._ids: set[int] = set()
        self._order: deque[int] = deque()

    def __len__(self) -> int:
        return len(self._order)

    def __contains__(self, msg_id: int) -> bool:
        return msg_id in self._ids

    def add(self, msg_id: int) -> None:
        if msg_id in self._ids:
            return
        self._ids.add(msg_id)
        self._order.append(msg_id)
        while len(self._order) > self.capacity:
            self._ids.discard(self._order.popleft())

    def clear(self) -> None:
        self._ids.clear()
        self._order.clear()


def _policy(kind: str, lossy: bool = True):
    """Tag built-in policies so consumers (the baselines) can tell a
    reliable transport from a lossy one without executing it."""

    def tag(fn):
        fn.kind = kind
        fn.lossy = lossy
        return fn

    return tag


# --------------------------------------------------------------- policies
def reliable() -> DeliveryPolicy:
    """Every message is delivered immediately (the default)."""

    @_policy("reliable", lossy=False)
    def policy(src, dst, msg, now):
        return ("deliver", 0)

    return policy


def drop(p: float, seed: int = 0, only: tuple | None = None) -> DeliveryPolicy:
    """Drop each matching message with probability ``p`` (seeded, so runs
    are reproducible). ``only`` restricts dropping to the given message
    classes — e.g. ``only=(ChunkOpBatch,)`` to lose write batches while
    control traffic survives."""
    rng = random.Random(seed)

    @_policy("drop")
    def policy(src, dst, msg, now):
        if only is not None and not isinstance(msg, only):
            return ("deliver", 0)
        if rng.random() < p:
            return ("drop", 0)
        return ("deliver", 0)

    return policy


def delay(ticks: int, only: tuple | None = None) -> DeliveryPolicy:
    """Deliver matching messages with their receive timestamp shifted
    ``ticks`` into the future (in-flight latency). Commit-flag flips
    registered by a delayed write become due later, widening the INVALID
    window the tagged-consistency design tolerates."""

    @_policy("delay")
    def policy(src, dst, msg, now):
        if only is not None and not isinstance(msg, only):
            return ("deliver", 0)
        return ("delay", ticks)

    return policy


def partition(*groups: tuple[str, ...]) -> DeliveryPolicy:
    """Network partition: messages between nodes in different groups are
    dropped. Nodes not named in any group, and the external "client", can
    reach everyone."""
    member: dict[str, int] = {}
    for gi, g in enumerate(groups):
        for nid in g:
            member[nid] = gi

    @_policy("partition")
    def policy(src, dst, msg, now):
        gs, gd = member.get(src), member.get(dst)
        if gs is not None and gd is not None and gs != gd:
            return ("drop", 0)
        return ("deliver", 0)

    return policy


def duplicate(
    p: float, seed: int = 0, only: tuple | None = None, lag: int = 1
) -> DeliveryPolicy:
    """Deliver each matching message normally AND enqueue a second copy
    that lands ``lag`` ticks later, after subsequent traffic — a
    duplicated out-of-order arrival the receiver's seen-window must make a
    no-op. ``p=1.0`` duplicates everything (the idempotency-proof mode)."""
    rng = random.Random(seed)

    @_policy("duplicate")
    def policy(src, dst, msg, now):
        if only is not None and not isinstance(msg, only):
            return ("deliver", 0)
        if rng.random() < p:
            return ("dup", lag)
        return ("deliver", 0)

    return policy


def reorder(
    p: float, seed: int = 0, only: tuple | None = None, lag: int = 1
) -> DeliveryPolicy:
    """Hold each matching message back with probability ``p``: it arrives
    ``lag`` ticks later, AFTER traffic sent after it. The sender sees a
    timeout (no ack) and retransmits; the retransmission races the held
    original, so the receiver sees the same message id twice, out of
    order."""
    rng = random.Random(seed)

    @_policy("reorder")
    def policy(src, dst, msg, now):
        if only is not None and not isinstance(msg, only):
            return ("deliver", 0)
        if rng.random() < p:
            return ("reorder", lag)
        return ("deliver", 0)

    return policy


def ack_loss(p: float, seed: int = 0, only: tuple | None = None) -> DeliveryPolicy:
    """Deliver and APPLY each matching message but lose its ack with
    probability ``p``. Indistinguishable from a lost message at the
    sender, which times out and retransmits — the receiver answers the
    retransmission from its seen-window without re-applying."""
    rng = random.Random(seed)

    @_policy("ack_loss")
    def policy(src, dst, msg, now):
        if only is not None and not isinstance(msg, only):
            return ("deliver", 0)
        if rng.random() < p:
            return ("ack_drop", 0)
        return ("deliver", 0)

    return policy


def chaos(
    seed: int = 0,
    p_drop: float = 0.1,
    p_dup: float = 0.1,
    p_reorder: float = 0.1,
    p_ack_drop: float = 0.1,
    only: tuple | None = None,
    lag: int = 1,
) -> DeliveryPolicy:
    """Composite randomized policy: each matching attempt independently
    drops, duplicates, reorders, loses its ack, or delivers cleanly —
    one seeded RNG, so a schedule is reproducible from its seed."""
    rng = random.Random(seed)

    @_policy("chaos")
    def policy(src, dst, msg, now):
        if only is not None and not isinstance(msg, only):
            return ("deliver", 0)
        r = rng.random()
        if r < p_drop:
            return ("drop", 0)
        r -= p_drop
        if r < p_dup:
            return ("dup", lag)
        r -= p_dup
        if r < p_reorder:
            return ("reorder", lag)
        r -= p_reorder
        if r < p_ack_drop:
            return ("ack_drop", 0)
        return ("deliver", 0)

    return policy


# -------------------------------------------------------------- accounting
@dataclass
class EdgeStats:
    msgs: int = 0
    wire_bytes: int = 0
    payload_bytes: int = 0
    dropped: int = 0
    delayed: int = 0
    retransmits: int = 0
    duplicates: int = 0     # extra copies enqueued by a `duplicate` policy
    reordered: int = 0      # originals held back by a `reorder` policy
    acks: int = 0           # acks sent on THIS edge (reverse of the data edge)
    acks_dropped: int = 0
    next_seq: int = 0       # per-edge sequence counter (stamped on envelopes)


@dataclass
class _Held:
    """A copy in flight: delivered after later traffic (reordering)."""

    env: Envelope
    msg: Message
    recv_time: int
    release_after: int  # global send counter this copy must let pass first


@dataclass
class Transport:
    """Message delivery + accounting between cluster participants.

    ``handlers`` maps participant id -> object with
    ``.handle(msg, now, env)`` (and optionally ``.alive``). The cluster
    passes its live ``nodes`` dict, so topology changes are visible without
    re-registration.

    ``retry_budget`` retransmissions (same message id) follow a lost attempt
    after ``ack_timeout`` simulated ticks each; 0 keeps the legacy
    fire-and-forget behavior.
    """

    handlers: Mapping[str, object] = field(default_factory=dict)
    # Non-node participants (client sessions with a presence cache) register
    # here under their session id; consulted only when ``handlers`` has no
    # entry for the destination, so node ids always win and an empty dict
    # keeps the legacy single-map behavior byte-identical.
    extra_handlers: dict[str, object] = field(default_factory=dict)
    policy: DeliveryPolicy = field(default_factory=reliable)
    retry_budget: int = 0
    ack_timeout: int = 2
    # optional cluster fault hook: (event, ctx_dict) -> None
    fault_hook: Callable[[str, dict], None] | None = None

    edges: dict[tuple[str, str], EdgeStats] = field(default_factory=dict)
    msgs_by_type: dict[str, int] = field(default_factory=dict)
    messages_sent: int = 0          # logical sends: ClusterStats.control_msgs
    net_bytes: int = 0              # payload + ack bytes on the wire
    wire_bytes: int = 0             # net_bytes + CONTROL_MSG_BYTES headers
    lookup_unicasts: int = 0        # CIT lookups carried (always unicast)
    lookup_broadcasts: int = 0      # never incremented — the paper's point
    dropped: int = 0
    delayed: int = 0
    deliveries: int = 0             # handler invocations (incl. dup/late copies)
    retransmits: int = 0            # wire-level re-sends (not in messages_sent)
    acks_sent: int = 0
    ack_bytes: int = 0
    acks_dropped: int = 0
    duplicates: int = 0             # extra copies enqueued by `duplicate`
    reordered: int = 0              # originals held back by `reorder`
    late_deliveries: int = 0        # held copies flushed after later traffic
    late_delivery_errors: int = 0   # held copies lost to a dead/raising handler
    timeout_ticks_waited: int = 0   # simulated ticks spent waiting on lost acks
    _msg_counter: int = 0
    _send_counter: int = 0
    _held: list[_Held] = field(default_factory=list)

    def edge(self, src: str, dst: str) -> EdgeStats:
        e = self.edges.get((src, dst))
        if e is None:
            e = self.edges[(src, dst)] = EdgeStats()
        return e

    # ----------------------------------------------------------- delivery
    def send(self, src: str, dst: str, msg: Message, now: int):
        """At-least-once unicast: deliver ``msg`` to ``dst`` and return the
        handler's response (the ack carries it).

        One logical send; up to ``retry_budget`` retransmissions of the
        same envelope chase a lost message or lost ack, each costing
        ``ack_timeout`` simulated ticks of sender waiting. Raises
        ``MessageDropped`` when the budget is exhausted (``maybe_applied``
        distinguishes "no attempt reached the receiver" from "an attempt
        reached it but its ack never came back"), or whatever the
        destination handler raises (``NodeDown``, ``ChunkMissing``, ...).
        Accounting: the logical send is counted unconditionally; payload
        and ack bytes only on delivered attempts.
        """
        self._msg_counter += 1
        self._send_counter += 1
        send_order = self._send_counter
        edge = self.edge(src, dst)
        env = Envelope(self._msg_counter, edge.next_seq, src, dst)
        edge.next_seq += 1
        edge.msgs += 1
        self.messages_sent += 1
        self.msgs_by_type[msg.TYPE] = self.msgs_by_type.get(msg.TYPE, 0) + 1
        self.lookup_unicasts += msg.lookups()
        if self.fault_hook is not None:
            self.fault_hook(
                "transport_send", {"src": src, "dst": dst, "type": msg.TYPE}
            )
        maybe_applied = False
        try:
            for attempt in range(self.retry_budget + 1):
                attempt_now = now + attempt * self.ack_timeout
                if attempt > 0:
                    edge.retransmits += 1
                    self.retransmits += 1
                    self.timeout_ticks_waited += self.ack_timeout
                action, ticks = self.policy(src, dst, msg, attempt_now)
                if action == "drop":
                    edge.dropped += 1
                    self.dropped += 1
                    continue  # wait out the ack timeout, retransmit
                if action == "reorder":
                    # The copy WILL arrive — late, after subsequent traffic.
                    # The sender cannot know that: it times out like a drop.
                    self._hold(env, msg, attempt_now + max(1, ticks), send_order)
                    edge.reordered += 1
                    self.reordered += 1
                    maybe_applied = True
                    continue
                recv_time = attempt_now + (ticks if action == "delay" else 0)
                if action == "delay":
                    edge.delayed += 1
                    self.delayed += 1
                attempt_env = Envelope(env.msg_id, env.seq, src, dst, attempt)
                response = self._deliver(attempt_env, msg, recv_time)
                if action == "dup":
                    # A second copy of the same envelope lands later, after
                    # subsequent traffic (duplicated + reordered arrival).
                    self._hold(env, msg, recv_time + max(1, ticks), send_order)
                    edge.duplicates += 1
                    self.duplicates += 1
                if action == "ack_drop":
                    # Applied at the receiver, but the sender never learns:
                    # the ack is lost in flight.
                    edge_rev = self.edge(dst, src)
                    edge_rev.acks_dropped += 1
                    self.acks_dropped += 1
                    maybe_applied = True
                    continue  # timeout, retransmit the same envelope
                return response
        finally:
            self._flush_held(send_order)
        # The final attempt's ack never came either: the sender waits out
        # one more timeout before concluding failure.
        self.timeout_ticks_waited += self.ack_timeout
        raise MessageDropped(src, dst, msg, env.msg_id, maybe_applied)

    def _deliver(self, env: Envelope, msg: Message, recv_time: int):
        """One attempt reaching the receiver: dispatch + wire accounting
        for the request payload and the ack flowing back."""
        handler = self.handlers.get(env.dst)
        if handler is None:
            handler = self.extra_handlers[env.dst]
        response = handler.handle(msg, recv_time, env)
        self.deliveries += 1
        edge = self.edge(env.src, env.dst)
        payload = msg.payload_bytes(env.dst, response) + msg.response_payload_bytes(
            response
        )
        edge.payload_bytes += payload
        edge.wire_bytes += CONTROL_MSG_BYTES + payload
        self.wire_bytes += CONTROL_MSG_BYTES + payload
        self.net_bytes += payload
        # The ack: ACK_MSG_BYTES on the reverse edge, part of net_bytes.
        rev = self.edge(env.dst, env.src)
        rev.acks += 1
        rev.wire_bytes += ACK_MSG_BYTES
        rev.payload_bytes += ACK_MSG_BYTES
        self.acks_sent += 1
        self.ack_bytes += ACK_MSG_BYTES
        self.wire_bytes += ACK_MSG_BYTES
        self.net_bytes += ACK_MSG_BYTES
        return response

    # ----------------------------------------------- in-flight (held) copies
    def _hold(self, env: Envelope, msg: Message, recv_time: int, send_order: int) -> None:
        self._held.append(_Held(env, msg, recv_time, send_order))

    def _flush_held(self, upto_send: int) -> None:
        """Deliver held copies whose reorder window has passed: a copy held
        during send N lands at the end of send N+1 (or on ``advance``) —
        i.e. strictly after the traffic that overtook it."""
        if not self._held:
            return
        due = [h for h in self._held if h.release_after < upto_send]
        if not due:
            return
        self._held = [h for h in self._held if h.release_after >= upto_send]
        for h in due:
            self._deliver_late(h)

    def advance(self, now: int) -> int:
        """Time passes (cluster tick): every copy still in flight lands.
        Returns the number of late deliveries."""
        held, self._held = self._held, []
        for h in held:
            self._deliver_late(h, now)
        return len(held)

    def _deliver_late(self, h: _Held, now: int | None = None) -> None:
        """A late (duplicated/reordered) copy arrives. Nobody awaits its
        ack — the original sender moved on — so errors are swallowed: a
        copy landing on a crashed node is simply lost."""
        self.late_deliveries += 1
        recv_time = h.recv_time if now is None else max(h.recv_time, now)
        try:
            self._deliver(h.env, h.msg, recv_time)
        except Exception:
            self.late_delivery_errors += 1

    def client_transfer(self, dst: str, nbytes: int, src: str = "client") -> None:
        """Object-ingress accounting: a client ships object bytes to a
        primary OSS. Modeled as pure data transfer (no control message, no
        ack), exactly as in the pre-transport accounting; delivery policies
        do not apply to the external client's ingress path. ``src`` names
        the client endpoint — distinct per-session names (``c0``, ``c1``,
        ...) give concurrent sessions their own ingress edges."""
        edge = self.edge(src, dst)
        edge.payload_bytes += nbytes
        edge.wire_bytes += nbytes
        self.wire_bytes += nbytes
        self.net_bytes += nbytes

    def in_flight_copies(self) -> int:
        """Held (duplicated/reordered) copies not yet delivered — the
        scheduler's quiescence probe: the simulation is quiet only when no
        actor is runnable AND nothing is still on the wire."""
        return len(self._held)
