"""Explicit message-passing transport for the shared-nothing cluster.

The paper's claims — no central metadata bottleneck, fingerprint-routed
unicasts instead of broadcasts, flag-based asynchronous consistency — are
statements about *messages between nodes*. This module makes those messages
first-class: every cluster interaction goes through ``Transport.send``,
which owns

* delivery (dispatch to the destination's ``handle(msg, recv_time)``),
* per-edge and per-type byte/message accounting (``EdgeStats``), and
* the message-level fault surface: pluggable delivery policies
  (``reliable`` / ``drop`` / ``delay`` / ``partition``) plus a hook that
  feeds the cluster's fault injector a ``transport_send`` event point.

Legacy ``ClusterStats`` fields (net_bytes / control_msgs / lookup_unicasts)
are views over the transport's totals — no call site hand-maintains
counters anymore.

Failure semantics (deterministic, simulation-friendly):

* **drop** raises ``MessageDropped`` at the sender — the message never
  reached the destination; senders treat it like an unreachable node
  (rollback / replica fallback / garbage for GC).
* **delay** delivers immediately in simulation order but time-shifts the
  *receive timestamp* by the configured ticks. Everything the destination
  stamps with its receive time shifts with it — most visibly the async
  commit-flag flips, which become due later, so a read racing a delayed
  write exercises the paper's repair-on-read consistency check.
* **partition** drops every message between nodes in different groups
  (the external client reaches all nodes).
"""

from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Callable, Mapping

from repro.core.messages import CONTROL_MSG_BYTES, Message

# policy(src, dst, msg, now) -> ("deliver", 0) | ("delay", ticks) | ("drop", 0)
DeliveryPolicy = Callable[[str, str, Message, int], tuple[str, int]]


class MessageDropped(RuntimeError):
    def __init__(self, src: str, dst: str, msg: Message):
        super().__init__(f"{msg.TYPE} {src}->{dst} dropped")
        self.src, self.dst, self.msg = src, dst, msg


# --------------------------------------------------------------- policies
def reliable() -> DeliveryPolicy:
    """Every message is delivered immediately (the default)."""

    def policy(src, dst, msg, now):
        return ("deliver", 0)

    return policy


def drop(p: float, seed: int = 0, only: tuple | None = None) -> DeliveryPolicy:
    """Drop each matching message with probability ``p`` (seeded, so runs
    are reproducible). ``only`` restricts dropping to the given message
    classes — e.g. ``only=(ChunkOpBatch,)`` to lose write batches while
    control traffic survives."""
    rng = random.Random(seed)

    def policy(src, dst, msg, now):
        if only is not None and not isinstance(msg, only):
            return ("deliver", 0)
        if rng.random() < p:
            return ("drop", 0)
        return ("deliver", 0)

    return policy


def delay(ticks: int, only: tuple | None = None) -> DeliveryPolicy:
    """Deliver matching messages with their receive timestamp shifted
    ``ticks`` into the future (in-flight latency). Commit-flag flips
    registered by a delayed write become due later, widening the INVALID
    window the tagged-consistency design tolerates."""

    def policy(src, dst, msg, now):
        if only is not None and not isinstance(msg, only):
            return ("deliver", 0)
        return ("delay", ticks)

    return policy


def partition(*groups: tuple[str, ...]) -> DeliveryPolicy:
    """Network partition: messages between nodes in different groups are
    dropped. Nodes not named in any group, and the external "client", can
    reach everyone."""
    member: dict[str, int] = {}
    for gi, g in enumerate(groups):
        for nid in g:
            member[nid] = gi

    def policy(src, dst, msg, now):
        gs, gd = member.get(src), member.get(dst)
        if gs is not None and gd is not None and gs != gd:
            return ("drop", 0)
        return ("deliver", 0)

    return policy


# -------------------------------------------------------------- accounting
@dataclass
class EdgeStats:
    msgs: int = 0
    wire_bytes: int = 0
    payload_bytes: int = 0
    dropped: int = 0
    delayed: int = 0


@dataclass
class Transport:
    """Message delivery + accounting between cluster participants.

    ``handlers`` maps participant id -> object with ``.handle(msg, now)``
    (and optionally ``.alive``). The cluster passes its live ``nodes`` dict,
    so topology changes are visible without re-registration.
    """

    handlers: Mapping[str, object] = field(default_factory=dict)
    policy: DeliveryPolicy = field(default_factory=reliable)
    # optional cluster fault hook: (event, ctx_dict) -> None
    fault_hook: Callable[[str, dict], None] | None = None

    edges: dict[tuple[str, str], EdgeStats] = field(default_factory=dict)
    msgs_by_type: dict[str, int] = field(default_factory=dict)
    messages_sent: int = 0          # legacy view: ClusterStats.control_msgs
    net_bytes: int = 0              # legacy view: payload bytes on the wire
    wire_bytes: int = 0             # payload + CONTROL_MSG_BYTES headers
    lookup_unicasts: int = 0        # CIT lookups carried (always unicast)
    lookup_broadcasts: int = 0      # never incremented — the paper's point
    dropped: int = 0
    delayed: int = 0

    def edge(self, src: str, dst: str) -> EdgeStats:
        e = self.edges.get((src, dst))
        if e is None:
            e = self.edges[(src, dst)] = EdgeStats()
        return e

    def send(self, src: str, dst: str, msg: Message, now: int):
        """Deliver ``msg`` to ``dst`` and return the handler's response.

        Raises ``MessageDropped`` when the delivery policy loses the
        message, or whatever the destination handler raises (``NodeDown``,
        ``ChunkMissing``, ...). Accounting: the message send is counted
        unconditionally; payload bytes only on successful delivery.
        """
        edge = self.edge(src, dst)
        edge.msgs += 1
        self.messages_sent += 1
        self.msgs_by_type[msg.TYPE] = self.msgs_by_type.get(msg.TYPE, 0) + 1
        self.lookup_unicasts += msg.lookups()
        if self.fault_hook is not None:
            self.fault_hook(
                "transport_send", {"src": src, "dst": dst, "type": msg.TYPE}
            )
        action, ticks = self.policy(src, dst, msg, now)
        if action == "drop":
            edge.dropped += 1
            self.dropped += 1
            raise MessageDropped(src, dst, msg)
        recv_time = now + (ticks if action == "delay" else 0)
        if action == "delay":
            edge.delayed += 1
            self.delayed += 1
        handler = self.handlers[dst]
        response = handler.handle(msg, recv_time)
        payload = msg.payload_bytes(dst, response) + msg.response_payload_bytes(response)
        edge.payload_bytes += payload
        edge.wire_bytes += CONTROL_MSG_BYTES + payload
        self.wire_bytes += CONTROL_MSG_BYTES + payload
        self.net_bytes += payload
        return response

    def client_transfer(self, dst: str, nbytes: int) -> None:
        """Object-ingress accounting: the client ships object bytes to a
        primary OSS. Modeled as pure data transfer (no control message),
        exactly as in the pre-transport accounting; delivery policies do
        not apply to the external client's ingress path."""
        edge = self.edge("client", dst)
        edge.payload_bytes += nbytes
        edge.wire_bytes += nbytes
        self.wire_bytes += nbytes
        self.net_bytes += nbytes
