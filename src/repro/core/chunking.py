"""Object chunking — host path.

The paper splits every object into small *fixed-size* chunks on the primary
OSS (512 KB default in the evaluation). We additionally provide windowed
content-defined chunking (CDC) whose boundary rule matches the Pallas CDC
kernel in ``repro.kernels.cdc`` (boundary at i iff gear-window-hash(i) & mask
== 0), so host and device agree on boundaries.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

DEFAULT_CHUNK_SIZE = 512 * 1024

# --- windowed gear hash (must match kernels/ref.py::cdc_window_hash) --------
_GEAR_MULT = 0x9E3779B1          # 32-bit golden-ratio multiplier
_WINDOW = 32                     # bytes of context per boundary decision


def _gear_table() -> list[int]:
    # Deterministic pseudo-random byte->u32 table (splitmix-ish), no RNG dep.
    tbl = []
    x = 0x243F6A88
    for _ in range(256):
        x = (x + 0x9E3779B9) & 0xFFFFFFFF
        z = x
        z = ((z ^ (z >> 16)) * 0x85EBCA6B) & 0xFFFFFFFF
        z = ((z ^ (z >> 13)) * 0xC2B2AE35) & 0xFFFFFFFF
        z = z ^ (z >> 16)
        tbl.append(z)
    return tbl


GEAR_TABLE = _gear_table()


def window_hash_at(data: bytes, i: int) -> int:
    """Gear hash of the W bytes ending at (and including) position i.
    Depends on at most _WINDOW bytes of context => parallelizable."""
    h = 0
    lo = max(0, i - _WINDOW + 1)
    for b in data[lo : i + 1]:
        h = ((h << 1) + GEAR_TABLE[b]) & 0xFFFFFFFF
    return h


@dataclass(frozen=True)
class ChunkingSpec:
    kind: str = "fixed"              # "fixed" | "cdc"
    chunk_size: int = DEFAULT_CHUNK_SIZE   # fixed size / CDC target size
    min_size: int = 0                # cdc only
    max_size: int = 0                # cdc only

    def normalized(self) -> "ChunkingSpec":
        if self.kind == "cdc":
            mn = self.min_size or self.chunk_size // 4
            mx = self.max_size or self.chunk_size * 4
            return ChunkingSpec("cdc", self.chunk_size, mn, mx)
        return self


def chunk_fixed(data: bytes, chunk_size: int = DEFAULT_CHUNK_SIZE) -> Iterator[bytes]:
    for off in range(0, len(data), chunk_size):
        yield data[off : off + chunk_size]


def chunk_cdc(data: bytes, spec: ChunkingSpec) -> Iterator[bytes]:
    """Windowed-gear CDC. Boundary after position i when h(i) & mask == 0,
    subject to [min_size, max_size]. mask targets ~chunk_size averages."""
    spec = spec.normalized()
    mask = (1 << max(1, (spec.chunk_size).bit_length() - 1)) - 1
    start = 0
    i = start + spec.min_size
    n = len(data)
    while i < n:
        if (window_hash_at(data, i) & mask) == 0 or (i - start + 1) >= spec.max_size:
            yield data[start : i + 1]
            start = i + 1
            i = start + spec.min_size
        else:
            i += 1
    if start < n:
        yield data[start:]


def chunk_object(data: bytes, spec: ChunkingSpec | None = None) -> list[bytes]:
    spec = (spec or ChunkingSpec()).normalized()
    if spec.kind == "fixed":
        out = list(chunk_fixed(data, spec.chunk_size))
    elif spec.kind == "cdc":
        out = list(chunk_cdc(data, spec))
    else:
        raise ValueError(f"unknown chunking kind {spec.kind!r}")
    if data and not out:
        raise AssertionError("non-empty object produced no chunks")
    assert b"".join(out) == data, "chunking must be lossless"
    return out
