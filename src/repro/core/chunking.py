"""Object chunking — host path.

The paper splits every object into small *fixed-size* chunks on the primary
OSS (512 KB default in the evaluation). We additionally provide windowed
content-defined chunking (CDC) whose boundary rule matches the Pallas CDC
kernel in ``repro.kernels.cdc`` (boundary at i iff gear-window-hash(i) & mask
== 0), so host and device agree on boundaries.

The host CDC is numpy-vectorized: one 256-entry gear-table gather turns the
byte stream into uint32 table values, then the W=32 window hashes for *all*
positions are built with log2(W)=5 shifted adds (doubling: a window of 2m is
a window of m plus the previous window of m shifted left by m) — the same
formulation the Pallas kernel uses, so results are bit-identical to the
scalar ``window_hash_at`` reference at every position. Boundary selection
(min/max-size enforcement) then walks only the candidate positions where
``hash & mask == 0``, so the per-chunk loop is O(#chunks), not O(#bytes).
``chunk_cdc_scalar`` keeps the original byte-at-a-time implementation as the
reference oracle for tests. ``window_hashes(backend="kernel")`` routes the
hash computation through ``repro.kernels.ops`` (Pallas on TPU, jnp oracle
elsewhere) for device-resident byte streams.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np

DEFAULT_CHUNK_SIZE = 512 * 1024

# --- windowed gear hash (must match kernels/ref.py::cdc_window_hash) --------
_GEAR_MULT = 0x9E3779B1          # 32-bit golden-ratio multiplier
_WINDOW = 32                     # bytes of context per boundary decision


def _gear_table() -> list[int]:
    # Deterministic pseudo-random byte->u32 table (splitmix-ish), no RNG dep.
    tbl = []
    x = 0x243F6A88
    for _ in range(256):
        x = (x + 0x9E3779B9) & 0xFFFFFFFF
        z = x
        z = ((z ^ (z >> 16)) * 0x85EBCA6B) & 0xFFFFFFFF
        z = ((z ^ (z >> 13)) * 0xC2B2AE35) & 0xFFFFFFFF
        z = z ^ (z >> 16)
        tbl.append(z)
    return tbl


GEAR_TABLE = _gear_table()
_GEAR_NP = np.array(GEAR_TABLE, dtype=np.uint32)


def window_hash_at(data: bytes, i: int) -> int:
    """Gear hash of the W bytes ending at (and including) position i.
    Depends on at most _WINDOW bytes of context => parallelizable.

    Scalar reference; the vectorized path is ``window_hashes``."""
    h = 0
    lo = max(0, i - _WINDOW + 1)
    for b in data[lo : i + 1]:
        h = ((h << 1) + GEAR_TABLE[b]) & 0xFFFFFFFF
    return h


def window_hashes(data: bytes, *, backend: str = "numpy") -> np.ndarray:
    """Vectorized ``window_hash_at`` for every position of ``data`` at once.

    Returns (len(data),) uint32. Positions i < W-1 use the short prefix
    window, exactly like the scalar reference and the kernel oracle.

    backend:
      * "numpy"  — host doubling scheme (default, no jax dependency)
      * "kernel" — route through ``repro.kernels.ops.cdc_window_hashes``
                   (Pallas on TPU, jnp oracle elsewhere; bit-identical)
    """
    buf = np.frombuffer(data, dtype=np.uint8)
    if buf.size == 0:
        return np.zeros(0, dtype=np.uint32)
    if backend == "kernel":
        from repro.kernels import ops as kops

        return np.asarray(kops.cdc_window_hashes(buf), dtype=np.uint32)
    if backend != "numpy":
        raise ValueError(f"unknown window-hash backend {backend!r}")
    # Doubling: H_m[i] = gear hash of the (up to) m bytes ending at i.
    # H_{2m}[i] = H_m[i] + (H_m[i-m] << m), with H_m[j] = 0 for j < 0.
    h = _GEAR_NP[buf]
    tmp = np.empty_like(h)
    m = 1
    while m < _WINDOW:
        np.left_shift(h[:-m], np.uint32(m), out=tmp[m:])
        np.add(h[m:], tmp[m:], out=h[m:])
        m <<= 1
    return h


def cdc_mask(chunk_size: int) -> int:
    """Boundary mask targeting ~chunk_size average chunks."""
    return (1 << max(1, chunk_size.bit_length() - 1)) - 1


# Tile for the fused hash+candidate scan: big enough to amortize numpy call
# overhead, small enough that the per-tile uint32 arrays stay cache-resident
# (the untiled scan streams ~20 stream-sized arrays through DRAM and is
# 2-3x slower).
_SCAN_TILE = 64 * 1024


def _mask_window(mask: int) -> int:
    """Effective doubling-window for the boundary test ``hash & mask == 0``.

    The gear window hash is H_w[i] = sum_j table[b(i-j)] << j (mod 2^32), so
    a byte j positions back only influences bits >= j. For a scalar mask
    2^L - 1 the test reads only the low L bits, which are fixed once the
    doubling scheme reaches a window of size >= L — levels beyond that
    cannot change any masked bit. Masks wider than 16 bits need the next
    power of two (32), i.e. the full window: no savings."""
    L = mask.bit_length()
    if L > 16 or mask != (1 << L) - 1:
        return _WINDOW
    w = 1
    while w < L:
        w <<= 1
    return w


def _cdc_candidates(data: bytes, mask: int, *, backend: str = "numpy") -> np.ndarray:
    """Positions i with window_hash(i) & mask == 0, as a sorted int array.

    The numpy path fuses the gear gather, the doubling scheme and the mask
    test tile-by-tile so intermediates never leave cache; only the (sparse)
    candidate indices are materialized. For scalar masks 2^L - 1 with
    L <= 16 the doubling scheme stops early (``_mask_window``) — identical
    candidates in fewer passes."""
    if backend != "numpy":
        h = window_hashes(data, backend=backend)
        return np.flatnonzero((h & np.uint32(mask)) == 0)
    buf = np.frombuffer(data, dtype=np.uint8)
    n = buf.size
    m32 = np.uint32(mask)
    w_eff = _mask_window(mask)
    halo = w_eff - 1
    hbuf = np.empty(_SCAN_TILE + halo, dtype=np.uint32)
    tmp = np.empty(_SCAN_TILE + halo, dtype=np.uint32)
    out: list[np.ndarray] = []
    for start in range(0, n, _SCAN_TILE):
        lo = max(0, start - halo)
        k = min(start + _SCAN_TILE, n) - lo
        h = hbuf[:k]
        np.take(_GEAR_NP, buf[lo : lo + k], out=h)
        m = 1
        while m < w_eff:
            np.left_shift(h[:-m], np.uint32(m), out=tmp[m:k])
            np.add(h[m:], tmp[m:k], out=h[m:])
            m <<= 1
        cand = np.flatnonzero((h[start - lo :] & m32) == 0)
        if cand.size:
            out.append(cand + start)
    if not out:
        return np.empty(0, dtype=np.int64)
    return np.concatenate(out)


def _cdc_cuts(cand: np.ndarray, n: int, min_size: int, max_size: int) -> list[int]:
    """Boundary selection over precomputed candidate positions.

    Returns the inclusive end index of every chunk except the implicit tail.
    Walks only candidate positions (hash & mask == 0) plus max-size forced
    cuts — bit-identical to the scalar ``chunk_cdc_scalar`` loop."""
    cuts: list[int] = []
    start = 0
    while True:
        lo = start + min_size
        if lo >= n:
            break
        # The scalar loop first checks positions from lo upward; the max-size
        # condition (i - start + 1 >= max_size) fires no earlier than lo.
        hard = max(lo, start + max_size - 1)
        j = int(np.searchsorted(cand, lo))
        cut = hard
        if j < cand.size and int(cand[j]) <= hard:
            cut = int(cand[j])
        if cut >= n:
            break
        cuts.append(cut)
        start = cut + 1
    return cuts


@dataclass(frozen=True)
class ChunkingSpec:
    kind: str = "fixed"              # "fixed" | "cdc"
    chunk_size: int = DEFAULT_CHUNK_SIZE   # fixed size / CDC target size
    min_size: int = 0                # cdc only
    max_size: int = 0                # cdc only

    def normalized(self) -> "ChunkingSpec":
        if self.kind == "cdc":
            mn = self.min_size or self.chunk_size // 4
            mx = self.max_size or self.chunk_size * 4
            return ChunkingSpec("cdc", self.chunk_size, mn, mx)
        return self


@dataclass(frozen=True)
class ChunkSpec:
    """The consolidated chunking-parameter surface.

    Each layer used to spell the same knobs its own way: core took
    ``ChunkingSpec`` (0 min/max defaulting to ``target//4``/``target*4``),
    the checkpointer took ``fp_chunk_bytes``/``device_cdc``/
    ``cdc_min_bytes``/``cdc_max_bytes`` (defaulting to ``//2``/``*2``),
    and the device kernels took raw ``mask``/``min_size``/``max_size``
    kwargs. A ``ChunkSpec`` holds the FULLY RESOLVED values once — the
    constructors encode each legacy defaulting convention, so existing
    call sites keep their exact boundaries — and every consumer
    (``chunk_object``, ``kernels.ops.cdc_*(spec=...)``,
    ``CheckpointConfig.chunk_spec``) accepts it directly. The legacy
    spellings are still accepted and mapped for one release.

    ``device`` marks specs whose CDC hash + cut selection should run as
    the fused on-device launch rather than the host numpy scan."""

    kind: str = "fixed"                    # "fixed" | "cdc"
    target_bytes: int = DEFAULT_CHUNK_SIZE
    min_bytes: int = 0                     # cdc only; resolved, never 0 for cdc
    max_bytes: int = 0
    device: bool = False

    @property
    def mask(self) -> int:
        """Boundary mask targeting ~target_bytes average CDC chunks."""
        return cdc_mask(self.target_bytes)

    @classmethod
    def fixed(cls, target_bytes: int = DEFAULT_CHUNK_SIZE) -> "ChunkSpec":
        return cls("fixed", target_bytes)

    @classmethod
    def cdc(
        cls,
        target_bytes: int,
        *,
        min_bytes: int = 0,
        max_bytes: int = 0,
        device: bool = False,
    ) -> "ChunkSpec":
        """Core convention: unset min/max default to target//4 / target*4
        (matches ``ChunkingSpec.normalized``)."""
        return cls(
            "cdc",
            target_bytes,
            min_bytes or target_bytes // 4,
            max_bytes or target_bytes * 4,
            device,
        )

    @classmethod
    def for_checkpoint(
        cls,
        fp_chunk_bytes: int,
        *,
        min_bytes: int = 0,
        max_bytes: int = 0,
        device: bool = True,
    ) -> "ChunkSpec":
        """Checkpoint convention: unset min/max default to fp_chunk_bytes//2
        / fp_chunk_bytes*2 (matches the legacy ``CheckpointConfig`` fields);
        ``device=False`` maps legacy ``device_cdc=False`` to fixed-size
        chunking, exactly what the fp fast path did."""
        if not device:
            return cls("fixed", fp_chunk_bytes)
        return cls(
            "cdc",
            fp_chunk_bytes,
            min_bytes or max(1, fp_chunk_bytes // 2),
            max_bytes or fp_chunk_bytes * 2,
            True,
        )

    @classmethod
    def from_chunking(
        cls, spec: "ChunkingSpec", *, device: bool = False
    ) -> "ChunkSpec":
        s = spec.normalized()
        return cls(s.kind, s.chunk_size, s.min_size, s.max_size, device)

    def to_chunking(self) -> "ChunkingSpec":
        return ChunkingSpec(self.kind, self.target_bytes, self.min_bytes, self.max_bytes)

    def kernel_kwargs(self) -> dict:
        """The raw kwargs the device kernels spell chunking in."""
        return {
            "mask": self.mask,
            "min_size": self.min_bytes,
            "max_size": self.max_bytes,
        }


def chunk_fixed(data: bytes, chunk_size: int = DEFAULT_CHUNK_SIZE) -> Iterator[bytes]:
    for off in range(0, len(data), chunk_size):
        yield data[off : off + chunk_size]


def chunk_cdc(data: bytes, spec: ChunkingSpec, *, backend: str = "numpy") -> Iterator[bytes]:
    """Windowed-gear CDC, vectorized. Boundary after position i when
    h(i) & mask == 0, subject to [min_size, max_size]. mask targets
    ~chunk_size averages. Boundaries are bit-identical to
    ``chunk_cdc_scalar``.

    backend:
      * "numpy"  — tiled host scan (default)
      * "kernel" — window hashes on device, cut selection on host
      * "device" — hashes AND cut selection on device in one fused launch
                   (``repro.kernels.ops.cdc_cut_offsets``); only the final
                   cut positions return to the host
    """
    spec = spec.normalized()
    if backend == "device":
        import jax.numpy as jnp

        from repro.kernels import ops as kops

        cuts: "np.ndarray | list[int]" = kops.cdc_cut_offsets(
            jnp.asarray(np.frombuffer(data, dtype=np.uint8)),
            mask=cdc_mask(spec.chunk_size),
            min_size=spec.min_size,
            max_size=spec.max_size,
        ) if data else []
    else:
        cand = _cdc_candidates(data, cdc_mask(spec.chunk_size), backend=backend)
        cuts = _cdc_cuts(cand, len(data), spec.min_size, spec.max_size)
    start = 0
    for cut in cuts:
        yield data[start : cut + 1]
        start = cut + 1
    if start < len(data):
        yield data[start:]


def chunk_cdc_scalar(data: bytes, spec: ChunkingSpec) -> Iterator[bytes]:
    """Byte-at-a-time CDC — the reference oracle the vectorized path must
    reproduce boundary-for-boundary. Kept for tests; ~3 orders of magnitude
    slower than ``chunk_cdc``."""
    spec = spec.normalized()
    mask = cdc_mask(spec.chunk_size)
    start = 0
    i = start + spec.min_size
    n = len(data)
    while i < n:
        if (window_hash_at(data, i) & mask) == 0 or (i - start + 1) >= spec.max_size:
            yield data[start : i + 1]
            start = i + 1
            i = start + spec.min_size
        else:
            i += 1
    if start < n:
        yield data[start:]


def chunk_object(data: bytes, spec: "ChunkingSpec | ChunkSpec | None" = None) -> list[bytes]:
    backend = "numpy"
    if isinstance(spec, ChunkSpec):
        backend = "device" if spec.device else "numpy"
        spec = spec.to_chunking()
    spec = (spec or ChunkingSpec()).normalized()
    if spec.kind == "fixed":
        out = list(chunk_fixed(data, spec.chunk_size))
    elif spec.kind == "cdc":
        out = list(chunk_cdc(data, spec, backend=backend))
    else:
        raise ValueError(f"unknown chunking kind {spec.kind!r}")
    if data and not out:
        raise AssertionError("non-empty object produced no chunks")
    assert b"".join(out) == data, "chunking must be lossless"
    return out
