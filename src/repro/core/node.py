"""StorageNode — one shared-nothing object storage server (OSD/OSS).

Persistent across crash/restart: the chunk store (disk) and the DM-Shard
(stored like a normal replicated object, per paper §2.2).
Volatile (lost on crash): the consistency manager's pending flag flips —
losing them is precisely the failure mode the tagged-consistency design
tolerates.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.consistency import ConsistencyManager
from repro.core.dmshard import DMShard, INVALID, VALID, CITEntry
from repro.core.fingerprint import Fingerprint, sha256_fp
from repro.core.gc import GarbageCollector


@dataclass
class NodeStats:
    disk_bytes_written: int = 0
    disk_bytes_read: int = 0
    chunk_writes: int = 0
    dedup_hits: int = 0
    cit_lookups: int = 0
    consistency_checks: int = 0
    repairs: int = 0


@dataclass
class StorageNode:
    node_id: str
    alive: bool = True
    chunk_store: dict[Fingerprint, bytes] = field(default_factory=dict)   # "disk"
    shard: DMShard = field(default_factory=DMShard)
    cm: ConsistencyManager = field(default_factory=ConsistencyManager)
    gc: GarbageCollector = field(default_factory=GarbageCollector)
    stats: NodeStats = field(default_factory=NodeStats)

    # ------------------------------------------------------------------ life
    def crash(self) -> None:
        """Power-fail: drop volatile state. Disk + DM-Shard survive."""
        self.alive = False
        self.cm.crash()

    def restart(self) -> None:
        self.alive = True

    def _require_alive(self) -> None:
        if not self.alive:
            raise NodeDown(self.node_id)

    # ------------------------------------------------------------- chunk I/O
    def receive_chunk(self, fp: Fingerprint, data: bytes, now: int, txn_id: int) -> str:
        """Fingerprint-routed chunk write (paper fig 2, OSS 4). Returns one of
        'dedup_hit' | 'repaired' | 'restored' | 'stored'."""
        self._require_alive()
        return self._apply_receive(fp, data, self.shard.cit_lookup(fp), now, txn_id)

    def receive_chunks(
        self, ops: list[tuple[Fingerprint, bytes]], now: int, txn_id: int
    ) -> list[str]:
        """Batched fingerprint-routed write: one unicast carrying many chunk
        ops. The CIT lookups are batched; per-op state transitions are exactly
        those of ``receive_chunk`` applied in order (a duplicate fingerprint
        later in the batch sees the entry its earlier twin created)."""
        self._require_alive()
        entries = self.shard.cit_lookup_many([fp for fp, _ in ops])
        out: list[str] = []
        seen: set[Fingerprint] = set()
        for (fp, data), entry in zip(ops, entries):
            if fp in seen:
                entry = self.shard.cit_lookup(fp)
            seen.add(fp)
            out.append(self._apply_receive(fp, data, entry, now, txn_id))
        return out

    def _apply_receive(
        self, fp: Fingerprint, data: bytes, entry: CITEntry | None, now: int, txn_id: int
    ) -> str:
        self.stats.cit_lookups += 1

        if entry is not None and entry.is_valid():
            # Duplicate write, valid flag: refcount increment granted.
            self.shard.cit_addref(fp)
            self.stats.dedup_hits += 1
            return "dedup_hit"

        if entry is not None:  # exists, flag INVALID -> consistency check
            self.stats.consistency_checks += 1
            if fp in self.chunk_store:  # stat() says bytes are present
                self.shard.cit_set_flag(fp, VALID, now)
                self.shard.cit_addref(fp)
                self.stats.repairs += 1
                return "repaired"
            # Bytes missing: store content first, then flip (async).
            self._disk_write(fp, data)
            self.shard.cit_addref(fp)
            self.cm.register(fp, now, txn_id)
            self.stats.repairs += 1
            return "restored"

        # Unique chunk: store with INVALID flag; flip is async (paper §2.4).
        self.shard.cit_insert(fp, len(data), now)
        self._disk_write(fp, data)
        self.shard.cit_addref(fp)
        self.cm.register(fp, now, txn_id)
        return "stored"

    def read_chunk(self, fp: Fingerprint, now: int) -> bytes:
        self._require_alive()
        data = self.chunk_store.get(fp)
        if data is None:
            raise ChunkMissing(self.node_id, fp)
        if sha256_fp(data) != fp and fp.namespace == "sha256":
            raise ChunkCorrupt(self.node_id, fp)
        self.stats.disk_bytes_read += len(data)
        entry = self.shard.cit_lookup(fp)
        if entry is not None and entry.flag == INVALID and entry.refcount > 0:
            # Read-path consistency check: bytes verified present & referenced.
            self.shard.cit_set_flag(fp, VALID, now)
            self.stats.repairs += 1
        return data

    def decref_chunk(self, fp: Fingerprint, now: int) -> None:
        self._require_alive()
        entry = self.shard.cit_lookup(fp)
        if entry is None:
            return
        rc = self.shard.cit_addref(fp, -1)
        if rc == 0:
            # Tombstone through the same tagged machinery: flag invalid,
            # GC ages it out; a re-reference before GC repairs it back.
            self.shard.cit_set_flag(fp, INVALID, now)

    def decref_chunks(self, fps: list[Fingerprint], now: int) -> None:
        """Batched refcount release (rollback / delete): one unicast."""
        for fp in fps:
            self.decref_chunk(fp, now)

    def has_chunk(self, fp: Fingerprint) -> bool:
        return fp in self.chunk_store

    def cit_entry(self, fp: Fingerprint) -> CITEntry | None:
        return self.shard.cit_lookup(fp)

    # ----------------------------------------------------------------- local
    def _disk_write(self, fp: Fingerprint, data: bytes) -> None:
        self.chunk_store[fp] = data
        self.stats.disk_bytes_written += len(data)
        self.stats.chunk_writes += 1

    def tick(self, now: int) -> None:
        if self.alive:
            self.cm.drain(self.shard, now)

    def run_gc(self, now: int) -> list[Fingerprint]:
        if not self.alive:
            return []
        return self.gc.run(self.shard, self.chunk_store, now)

    def stored_bytes(self) -> int:
        return sum(len(v) for v in self.chunk_store.values())


class NodeDown(RuntimeError):
    def __init__(self, node_id: str):
        super().__init__(f"storage node {node_id} is down")
        self.node_id = node_id


class ChunkMissing(RuntimeError):
    def __init__(self, node_id: str, fp: Fingerprint):
        super().__init__(f"chunk {fp} missing on {node_id}")
        self.node_id, self.fp = node_id, fp


class ChunkCorrupt(RuntimeError):
    def __init__(self, node_id: str, fp: Fingerprint):
        super().__init__(f"chunk {fp} corrupt on {node_id}")
        self.node_id, self.fp = node_id, fp
