"""StorageNode — one shared-nothing object storage server (OSD/OSS).

Persistent across crash/restart: the chunk store (disk) and the DM-Shard
(stored like a normal replicated object, per paper §2.2).
Volatile (lost on crash): the consistency manager's pending flag flips —
losing them is precisely the failure mode the tagged-consistency design
tolerates.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.consistency import ConsistencyManager
from repro.core.dmshard import DMShard, INVALID, VALID, CITEntry, OMAPEntry
from repro.core.fingerprint import Fingerprint, name_fp, sha256_fp
from repro.core.gc import GarbageCollector
from repro.core.messages import (
    ChunkOp,
    ChunkOpBatch,
    ChunkRead,
    ChunkReadBatch,
    ChunkReadBatchReply,
    DecrefBatch,
    DigestReply,
    DigestRequest,
    Message,
    MigrateChunk,
    OmapDelete,
    OmapGet,
    OmapPut,
    RawPut,
    RefAudit,
    RefOnlyWrite,
    RepairChunk,
    TombstoneReap,
    TxnCancel,
)
from repro.core.transport import BoundedIdSet, Envelope, SeenWindow


# Sink for ref-only ops, which never register async flips (they either ride
# an existing valid entry or repair one whose bytes are already present).
_NO_REGISTER: list = []


@dataclass
class DirtyTracker:
    """Per-placement-group dirty epochs — the cheap metadata that makes
    recovery incremental. Every mutating message bumps the dirty epoch of
    the placement group it touched (group key = the placement tuple under
    the node's cluster-map share, computed at mutation time); an
    incremental digest probe (``DigestRequest.since_epoch``) then
    re-digests only groups dirty at or after the probe's floor. A
    cluster-map change invalidates every key (groups are placement tuples
    OF a map), so ``rekey`` marks the whole node dirty at the remap epoch
    — rebalance traffic is never silently skipped. Memory is O(groups
    touched since the map epoch), not O(entries).

    Durability: marks ride the shard, not RAM — every mark corresponds to
    a durable shard/chunk-store mutation, so a crash loses neither (the
    divergence a crash CREATES is what it missed while down, and that
    dirt lives on the peers' trackers; the two-phase incremental summary
    collection probes the rejoined member for peer-reported groups)."""

    groups: dict = field(default_factory=dict)   # placement tuple -> last dirty epoch
    all_dirty_at: int = 0                        # node birth / map change: everything dirty

    def rekey(self, now: int) -> None:
        self.groups.clear()
        self.all_dirty_at = max(self.all_dirty_at, now)

    def mark(self, group: tuple, now: int) -> None:
        if now > self.groups.get(group, -1):
            self.groups[group] = now

    def dirty_since(self, since: int) -> "set | None":
        """The groups to re-digest for a probe with floor ``since``; None
        means 'everything' (the map changed, or the node is younger than
        the floor covers)."""
        if since <= self.all_dirty_at:
            return None
        return {g for g, e in self.groups.items() if e >= since}


@dataclass
class NodeStats:
    disk_bytes_written: int = 0
    disk_bytes_read: int = 0
    chunk_writes: int = 0
    dedup_hits: int = 0
    cit_lookups: int = 0
    consistency_checks: int = 0
    repairs: int = 0
    dup_msgs_suppressed: int = 0   # duplicate deliveries answered from the window
    poisoned_discards: int = 0     # late copies of cancelled messages discarded
    out_of_order: int = 0          # arrivals with a seq below the edge high-water
    cancels_applied: int = 0       # TxnCancel compensations that found the op applied
    seen_evictions: int = 0        # ids the bounded seen-window pushed out (pressure)
    seen_high_water: int = 0       # peak seen-window occupancy
    digests_served: int = 0        # recovery digest requests answered
    repairs_adopted: int = 0       # RepairChunk deliveries that stored bytes or a CIT entry
    audit_increfs: int = 0         # references an audit correction restored
    audit_decrefs: int = 0         # references an audit-tagged DecrefBatch released
    decrefs_unbacked: int = 0      # releases of a ref this replica never kept
                                   # (missed incref / cancelled ack-lost op)
    audit_flag_flips: int = 0      # stuck-INVALID flags an audit correction repaired
    tombstones_written: int = 0    # delete tombstone records committed/adopted
    tombstones_reaped: int = 0     # aged tombstones removed by TombstoneReap
    stale_puts_refused: int = 0    # version-gated OmapPut/OmapDelete rejections
    groups_digested: int = 0       # placement-group summaries this node computed
    groups_skipped: int = 0        # clean groups an incremental probe skipped


@dataclass
class StorageNode:
    node_id: str
    alive: bool = True
    chunk_store: dict[Fingerprint, bytes] = field(default_factory=dict)   # "disk"
    shard: DMShard = field(default_factory=DMShard)
    cm: ConsistencyManager = field(default_factory=ConsistencyManager)
    gc: GarbageCollector = field(default_factory=GarbageCollector)
    stats: NodeStats = field(default_factory=NodeStats)
    # At-least-once receive state. ``seen`` (message id -> first response)
    # makes every retransmitted/duplicated delivery a state-free re-ack;
    # ``_poisoned`` holds cancelled ids whose copy may still be in flight.
    # Both persist across crash like the DM-Shard: delivery dedup metadata
    # is journaled with the ops it guards (losing it would re-open the
    # double-apply window for every pre-crash unicast).
    seen: SeenWindow = field(default_factory=SeenWindow)
    _poisoned: BoundedIdSet = field(default_factory=BoundedIdSet)
    _edge_seq_seen: dict[str, int] = field(default_factory=dict)
    # Cluster-map share (like an OSDMap epoch share) + per-placement-group
    # dirty epochs. The map share only feeds dirty-group KEYING — message
    # routing stays the sender's job; a node with no share (standalone unit
    # tests, baselines) just serves every digest probe in full.
    cmap: object = None
    dirty: DirtyTracker = field(default_factory=DirtyTracker)
    # Bounded clock skew (ROADMAP item 4). ``clock_offset`` is this node's
    # local-clock error relative to event time: everything that would read a
    # WALL clock in a real deployment — tombstone ``deleted_at`` stamping and
    # tombstone aging — goes through ``local_now``. Message delivery order and
    # version authority never consult it (versions are the cluster-monotonic
    # txn counter, not timestamps). ``skew_guard`` is the deployment's skew
    # BOUND: reap candidacy requires age past ``horizon + skew_guard``, so a
    # clock up to that much fast cannot age a tombstone out before every
    # correctly-clocked replica would agree it is reapable.
    clock_offset: int = 0
    skew_guard: int = 0

    def local_now(self, now: int) -> int:
        """This node's skewed local-clock reading at event time ``now``."""
        return now + self.clock_offset

    def set_cmap(self, cmap, now: int) -> None:
        """Adopt a cluster-map share; a CHANGED map re-keys every placement
        group, so the dirty tracker marks the whole node dirty at the remap
        epoch (rebalance traffic is incremental-repair traffic)."""
        if cmap != self.cmap:
            self.cmap = cmap
            self.dirty.rekey(now)

    def _mark_chunk_dirty(self, fp: Fingerprint, now: int) -> None:
        if self.cmap is not None:
            from repro.core.placement import place

            self.dirty.mark(tuple(place(fp, self.cmap)), now)

    def _mark_name_dirty(self, name: str, now: int) -> None:
        if self.cmap is not None:
            from repro.core.placement import place

            self.dirty.mark(tuple(place(name_fp(name), self.cmap)), now)

    # ------------------------------------------------------------------ life
    def crash(self) -> None:
        """Power-fail: drop volatile state. Disk + DM-Shard survive."""
        self.alive = False
        self.cm.crash()

    def restart(self) -> None:
        self.alive = True

    def _require_alive(self) -> None:
        if not self.alive:
            raise NodeDown(self.node_id)

    # ----------------------------------------------------------- message I/O
    def handle(self, msg: Message, now: int, env: Envelope | None = None):
        """Single entry point for every wire message (see messages.py).
        The transport delivers here; ``now`` is the receive timestamp (a
        delayed message arrives with a later one).

        At-least-once guard: when the delivery carries an ``Envelope``, its
        message id is checked against the bounded seen-window FIRST — a
        retransmitted or duplicated copy returns the cached response of the
        first application without touching any state (CIT refcounts, OMAP,
        chunk store, pending flips). Copies of a cancelled (poisoned) id
        are discarded. This is what makes every mutating message type
        (ChunkOpBatch / RefOnlyWrite / DecrefBatch / OmapPut / OmapDelete /
        MigrateChunk / TxnCancel) exactly-once at the state layer over an
        at-least-once wire."""
        self._require_alive()
        # Reads mutate nothing a duplicate could corrupt (repair-on-read is
        # idempotent), so they stay OUT of the seen-window: recording them
        # would let read traffic evict mutating message ids and silently
        # re-open the double-apply window the bound is sized for. Digest
        # probes are reads too — a duplicated DigestRequest just recomputes
        # the same summary. RepairChunk / RefAudit / audit DecrefBatch are
        # mutating and ride the window like every other recovery-era write.
        mutating = not isinstance(
            msg, (ChunkRead, ChunkReadBatch, OmapGet, DigestRequest)
        )
        if env is not None:
            if env.msg_id in self._poisoned:
                # A late copy of a message the sender already cancelled:
                # applying it would resurrect a rolled-back transaction.
                self.stats.poisoned_discards += 1
                return None
            last = self._edge_seq_seen.get(env.src, -1)
            if env.seq < last:
                self.stats.out_of_order += 1
            else:
                self._edge_seq_seen[env.src] = env.seq
            if mutating:
                cached = self.seen.get(env.msg_id)
                if cached is not self.seen.ABSENT:
                    self.stats.dup_msgs_suppressed += 1
                    return cached
        response = self._dispatch(msg, now, env.msg_id if env is not None else None)
        if env is not None and mutating:
            self.stats.seen_evictions += self.seen.record(env.msg_id, response)
            self.stats.seen_high_water = max(
                self.stats.seen_high_water, self.seen.high_water
            )
        return response

    def _dispatch(self, msg: Message, now: int, msg_id: int | None = None):
        if isinstance(msg, ChunkOpBatch):
            return self._handle_chunk_ops(msg.ops, now, msg.txn, msg_id)
        if isinstance(msg, OmapGet):
            return self.shard.omap_get(msg.name)
        if isinstance(msg, OmapPut):
            e = msg.entry
            applied, prev = self.shard.omap_apply(
                OMAPEntry(
                    e.name, e.object_fp, list(e.chunk_fps), e.size, e.version,
                    e.deleted, e.deleted_at,
                )
            )
            if applied:
                self._mark_name_dirty(e.name, now)
                if e.deleted:
                    self.stats.tombstones_written += 1
            else:
                # Version gate: a delayed commit (or a repair racing a
                # newer write) may not clobber a newer record or tombstone.
                self.stats.stale_puts_refused += 1
            # The replaced record rides the response so the committer can
            # release the exact version it displaced (entry or tombstone) —
            # the only race-safe source under concurrent replacers.
            return applied, prev
        if isinstance(msg, OmapDelete):
            applied, prev = self.shard.omap_tombstone(
                msg.name, msg.version, self.local_now(now)
            )
            if applied:
                self.stats.tombstones_written += 1
                self._mark_name_dirty(msg.name, now)
            else:
                self.stats.stale_puts_refused += 1
            return prev
        if isinstance(msg, TombstoneReap):
            reaped = self.shard.omap_reap(msg.name, msg.version)
            if reaped is not None:
                self.stats.tombstones_reaped += 1
                self._mark_name_dirty(msg.name, now)
                # The retained fps ride the response: the coordinator fans
                # them out as a last-chance presence invalidation.
                return ("reaped", tuple(reaped.chunk_fps))
            return "noop"
        if isinstance(msg, DecrefBatch):
            self.decref_chunks(list(msg.fps), now, audit=msg.audit)
            return True
        if isinstance(msg, RefOnlyWrite):
            return tuple(self._apply_ref_only(fp, now) for fp in msg.fps)
        if isinstance(msg, ChunkRead):
            return self.read_chunk(msg.fp, now)
        if isinstance(msg, ChunkReadBatch):
            return self._serve_read_batch(msg.fps, now)
        if isinstance(msg, MigrateChunk):
            return self._apply_migrate(msg, now)
        if isinstance(msg, DigestRequest):
            return self._serve_digest(msg, now)
        if isinstance(msg, RepairChunk):
            return self._apply_repair(msg, now)
        if isinstance(msg, RefAudit):
            return self._apply_ref_audit(msg, now)
        if isinstance(msg, TxnCancel):
            return self._apply_cancel(msg, now)
        if isinstance(msg, RawPut):
            # Unconditional store: baselines key RawPut by *name* hash too
            # (NoDedup), where a rewrite must replace the old bytes.
            self._disk_write(msg.fp, msg.data)
            return True
        raise TypeError(f"unhandled message type {type(msg).__name__}")

    # ------------------------------------------------------------- chunk I/O
    def receive_chunk(self, fp: Fingerprint, data: bytes, now: int, txn_id: int) -> str:
        """Fingerprint-routed chunk write (paper fig 2, OSS 4). Returns one of
        'dedup_hit' | 'repaired' | 'restored' | 'stored'."""
        self._require_alive()
        return self._handle_chunk_ops((ChunkOp(fp, data),), now, txn_id)[0]

    def receive_chunks(
        self, ops: list[tuple[Fingerprint, bytes]], now: int, txn_id: int
    ) -> list[str]:
        """Batched fingerprint-routed write: one unicast carrying many chunk
        ops (legacy tuple API; the wire form is a ``ChunkOpBatch``)."""
        self._require_alive()
        return self._handle_chunk_ops(
            tuple(ChunkOp(fp, data) for fp, data in ops), now, txn_id
        )

    def _handle_chunk_ops(
        self,
        ops: tuple[ChunkOp, ...],
        now: int,
        txn_id: int,
        msg_id: int | None = None,
    ) -> list[str]:
        """Apply one unicast's chunk ops in order. The CIT lookups are
        batched, and all async flag-flip registrations from the batch go to
        the consistency manager in one ``register_many`` call. Per-op state
        transitions are exactly those of ``receive_chunk`` applied in order
        (a duplicate fingerprint later in the batch sees the entry its
        earlier twin created)."""
        entries = self.shard.cit_lookup_many([op.fp for op in ops])
        out: list[str] = []
        register: list[Fingerprint] = []
        seen: set[Fingerprint] = set()
        for op, entry in zip(ops, entries):
            if op.fp in seen:
                entry = self.shard.cit_lookup(op.fp)
            seen.add(op.fp)
            if op.data is None:
                out.append(self._apply_ref_only(op.fp, now, entry))
            else:
                out.append(self._apply_receive(op.fp, op.data, entry, now, register))
        if register:
            self.cm.register_many(register, now, txn_id, msg_id)
        return out

    def _apply_receive(
        self,
        fp: Fingerprint,
        data: bytes | None,
        entry: CITEntry | None,
        now: int,
        register: list[Fingerprint],
    ) -> str:
        """One chunk op's state transition. ``data is None`` is a ref-only
        op: where a payload op would store bytes, it returns 'miss' instead
        (entry absent, or invalid with no local bytes to back a repair) and
        the sender falls back to shipping the chunk."""
        self.stats.cit_lookups += 1

        if entry is not None and entry.is_valid():
            # Duplicate write, valid flag: refcount increment granted.
            self.shard.cit_addref(fp, now=now)
            self._mark_chunk_dirty(fp, now)
            self.stats.dedup_hits += 1
            return "dedup_hit"

        if entry is not None:  # exists, flag INVALID -> consistency check
            self.stats.consistency_checks += 1
            if fp in self.chunk_store:  # stat() says bytes are present
                self.shard.cit_set_flag(fp, VALID, now)
                self.shard.cit_addref(fp, now=now)
                self._mark_chunk_dirty(fp, now)
                self.stats.repairs += 1
                return "repaired"
            if data is None:
                return "miss"
            # Bytes missing: store content first, then flip (async).
            self._disk_write(fp, data)
            self.shard.cit_addref(fp, now=now)
            register.append(fp)
            self._mark_chunk_dirty(fp, now)
            self.stats.repairs += 1
            return "restored"

        if data is None:
            return "miss"
        # Unique chunk: store with INVALID flag; flip is async (paper §2.4).
        self.shard.cit_insert(fp, len(data), now)
        self._disk_write(fp, data)
        self.shard.cit_addref(fp, now=now)
        register.append(fp)
        self._mark_chunk_dirty(fp, now)
        return "stored"

    def _apply_ref_only(
        self, fp: Fingerprint, now: int, entry: CITEntry | None = None
    ) -> str:
        if entry is None:
            entry = self.shard.cit_lookup(fp)
        return self._apply_receive(fp, None, entry, now, _NO_REGISTER)

    def _apply_cancel(self, msg: TxnCancel, now: int) -> str:
        """Resolve the sender's "ack lost, op applied?" ambiguity locally.

        If the referenced message id is in the seen-window, its op DID
        apply here: compensate — release exactly the refs its cached
        outcomes granted (a 'miss' took none) and drop the OMAP entry a
        cancelled commit wrote. If it is absent, the op never applied (or
        its copy is still in flight): poison the id so a late arrival is
        discarded instead of resurrecting the cancelled transaction.
        TxnCancel itself rides the same seen-window, so a retransmitted
        cancel never double-compensates.

        ``undelete`` compensates a cancelled DELETE: the tombstone is
        voided only if it is still in place at exactly the cancelled
        transaction's version (``ref_version`` — a newer write or newer
        delete won the race and stands), restoring the pre-delete entry
        the delete's cached response preserved."""
        cached = self.seen.get(msg.ref_msg_id)
        if cached is self.seen.ABSENT:
            self._poisoned.add(msg.ref_msg_id)
            return "noop"
        self.stats.cancels_applied += 1
        if msg.omap_name is not None:
            if msg.undelete:
                cur = self.shard.omap_get(msg.omap_name)
                if (
                    cur is not None and cur.deleted
                    and cur.version == msg.ref_version
                ):
                    if isinstance(cached, OMAPEntry):
                        self.shard.omap_put(cached)
                    else:
                        self.shard.omap_delete(msg.omap_name)
                    self._mark_name_dirty(msg.omap_name, now)
            else:
                # Cancelled commit: the cached (applied, replaced) response
                # says exactly what the put displaced — restore it. A put
                # the version gate refused never landed, so there is
                # nothing to undo; a put over a tombstone restores the
                # tombstone (deleting the name outright would void the
                # delete's resurrection guard).
                applied, prev = (
                    cached if isinstance(cached, tuple) and len(cached) == 2
                    else (True, None)
                )
                if applied:
                    if isinstance(prev, OMAPEntry):
                        self.shard.omap_put(prev)
                    else:
                        self.shard.omap_delete(msg.omap_name)
                    self._mark_name_dirty(msg.omap_name, now)
        outcomes = cached if isinstance(cached, (list, tuple)) else []
        for fp, outcome in zip(msg.fps, outcomes):
            if outcome != "miss":
                self.decref_chunk(fp, now)
        return "cancelled"

    def _apply_migrate(self, msg: MigrateChunk, now: int) -> str:
        """Rebalance/scrub: adopt chunk bytes and the CIT entry traveling
        with them (content placement — metadata needs no location rewrite)."""
        if msg.data is not None and msg.fp not in self.chunk_store:
            self.chunk_store[msg.fp] = msg.data
            self.stats.disk_bytes_written += len(msg.data)
        if msg.cit is not None:
            msg.cit.clone_into(self.shard, msg.fp, now)
        self._mark_chunk_dirty(msg.fp, now)
        return "ok"

    # ------------------------------------------------------------- recovery
    def _serve_digest(self, msg: DigestRequest, now: int) -> DigestReply:
        """Answer a recovery coordinator's digest probe over this node's OWN
        holdings (read-only — a duplicated probe recomputes harmlessly).

        An incremental probe (``since_epoch``) is filtered through the
        dirty tracker: only groups mutated at or after the floor are
        re-digested, clean ones are counted as skipped. The probe's map is
        adopted as this node's cluster-map share first — if it re-keys the
        placement groups, the tracker conservatively reports everything
        dirty. Summary omap probes additionally list this node's aged
        tombstones (the GC-horizon reap candidates)."""
        self.stats.digests_served += 1
        if msg.cmap is not None:
            self.set_cmap(msg.cmap, now)
        if msg.kind == "recipes":
            counts = self.shard.recipe_refs(msg.cmap, msg.live, self.node_id)
            return DigestReply(kind="recipes", groups={}, entries=counts, epoch=now)
        only = None
        if msg.since_epoch is not None and not msg.groups and not msg.detail_all:
            only = self.dirty.dirty_since(msg.since_epoch)
        if msg.kind == "omap":
            summary, entries, skipped = self.shard.omap_digest(
                msg.cmap, msg.groups, msg.detail_all,
                only_groups=only, summary_only=msg.summary_only,
            )
            tombs = None
            if not msg.groups and not msg.detail_all:
                # Aging reads the node's LOCAL clock (the one real thing a
                # deployment has), so the horizon is widened by the skew
                # bound: a clock ``skew_guard`` fast still cannot nominate
                # a tombstone before its true age reaches the horizon.
                tombs = self.shard.aged_tombstones(
                    self.local_now(now), self.gc.tombstone_horizon + self.skew_guard
                )
            self.stats.groups_digested += len(summary)
            self.stats.groups_skipped += skipped
            return DigestReply(
                kind="omap", groups=summary, entries=entries, epoch=now,
                skipped_groups=skipped, tombstones=tombs,
            )
        summary, entries, skipped = self.shard.chunk_digest(
            self.chunk_store, msg.cmap, msg.groups, msg.detail_all,
            only_groups=only, summary_only=msg.summary_only,
        )
        self.stats.groups_digested += len(summary)
        self.stats.groups_skipped += skipped
        return DigestReply(
            kind="chunks", groups=summary, entries=entries, epoch=now,
            skipped_groups=skipped,
        )

    def _apply_repair(self, msg: RepairChunk, now: int) -> tuple[str, str]:
        """Digest-diff repair: adopt-if-missing, precisely reported. The
        response tells the coordinator what actually changed so a repair
        raced by a rebalance (or a duplicated delivery replayed from the
        seen-window) is visibly a no-op instead of a silent double-count."""
        bytes_outcome = "present" if msg.fp in self.chunk_store else ""
        if msg.data is not None and not bytes_outcome:
            self.chunk_store[msg.fp] = msg.data
            self.stats.disk_bytes_written += len(msg.data)
            bytes_outcome = "stored"
        cit_outcome = ""
        if msg.cit is not None:
            cit_outcome = (
                "cit_stored"
                if msg.cit.clone_into(self.shard, msg.fp, now) is not None
                else "cit_present"
            )
        if bytes_outcome == "stored" or cit_outcome == "cit_stored":
            self.stats.repairs_adopted += 1
        return (bytes_outcome, cit_outcome)

    def _apply_ref_audit(self, msg: RefAudit, now: int) -> tuple[str, ...]:
        """Apply upward refcount corrections and flag repairs from the
        cluster-wide audit. Each item carries the reference count the
        cluster's OMAP recipes prove for this fingerprint; raising to it is
        idempotent by construction (and the message rides the seen-window
        regardless). Excess references arrive separately as audit-tagged
        DecrefBatch messages."""
        out: list[str] = []
        for fp, expected in msg.items:
            entry = self.shard.cit_lookup(fp)
            if entry is None:
                out.append("absent")
                continue
            action = "ok"
            if entry.refcount < expected:
                self.stats.audit_increfs += expected - entry.refcount
                self.shard.cit_addref(fp, expected - entry.refcount, now=now)
                self._mark_chunk_dirty(fp, now)
                action = "incref"
            if expected > 0 and entry.flag == INVALID and fp in self.chunk_store:
                # Recipes prove the chunk live and the bytes are on disk:
                # the async flip was lost (crash / cancelled txn race) —
                # the same consistency check the read path runs.
                self.shard.cit_set_flag(fp, VALID, now)
                self.stats.audit_flag_flips += 1
                action = "flag_valid" if action == "ok" else action + "+flag"
            out.append(action)
        return tuple(out)

    def read_chunk(self, fp: Fingerprint, now: int) -> bytes:
        self._require_alive()
        data = self.chunk_store.get(fp)
        if data is None:
            raise ChunkMissing(self.node_id, fp)
        if sha256_fp(data) != fp and fp.namespace == "sha256":
            raise ChunkCorrupt(self.node_id, fp)
        self.stats.disk_bytes_read += len(data)
        entry = self.shard.cit_lookup(fp)
        if entry is not None and entry.flag == INVALID and entry.refcount > 0:
            # Read-path consistency check: bytes verified present & referenced.
            self.shard.cit_set_flag(fp, VALID, now)
            self.stats.repairs += 1
        return data

    def _serve_read_batch(
        self, fps: tuple[Fingerprint, ...], now: int
    ) -> ChunkReadBatchReply:
        """Serve a coalesced restore fetch: per-fp hit/miss instead of the
        single-chunk raise, so one degraded chunk fails alone while the
        rest of the batch is kept. Hits run the same read-path consistency
        check as ``read_chunk`` (repair-on-read flag flip included). A
        corrupt chunk reports a miss like absent bytes — the sender's
        replica walk treats both as "this replica cannot serve it"."""
        chunks: list[bytes | None] = []
        for fp in fps:
            try:
                chunks.append(self.read_chunk(fp, now))
            except (ChunkMissing, ChunkCorrupt):
                chunks.append(None)
        return ChunkReadBatchReply(tuple(chunks))

    def decref_chunk(self, fp: Fingerprint, now: int) -> None:
        self._require_alive()
        entry = self.shard.cit_lookup(fp)
        if entry is None:
            return
        if entry.refcount == 0:
            # A release for a reference this replica never kept: either it
            # missed the incref while unreachable, or a TxnCancel already
            # compensated an ack-lost application — yet the object COMMITTED
            # on the replicas that did ack, so its later delete/replace
            # releases on every placement target. The sender's recipe is the
            # authority that the logical reference existed; locally there is
            # nothing to release, and going negative would punish this
            # replica for under-replication the refcount audit exists to
            # repair (``refs_under``). Mirror the normal zero transition so
            # the entry ages out through GC if nothing re-references it.
            self.stats.decrefs_unbacked += 1
            self._mark_chunk_dirty(fp, now)
            self.shard.cit_set_flag(fp, INVALID, now)
            return
        rc = self.shard.cit_addref(fp, -1, now=now)
        self._mark_chunk_dirty(fp, now)
        if rc == 0:
            # Tombstone through the same tagged machinery: flag invalid,
            # GC ages it out; a re-reference before GC repairs it back.
            self.shard.cit_set_flag(fp, INVALID, now)

    def decref_chunks(
        self, fps: list[Fingerprint], now: int, audit: bool = False
    ) -> None:
        """Batched refcount release (rollback / delete): one unicast.
        ``audit=True`` marks releases the cluster-wide refcount audit
        PROVED unreferenced by any recipe: entries driven to zero skip the
        GC aging wait (the recipe walk is the cross-match evidence aging
        normally buys) and any still-queued async flips for them are
        purged — they belong to the leaked transaction being reclaimed."""
        for fp in fps:
            self.decref_chunk(fp, now)
        if not audit:
            return
        self.stats.audit_decrefs += len(fps)
        dead = [fp for fp in dict.fromkeys(fps)
                if (e := self.shard.cit_lookup(fp)) is not None and e.refcount == 0]
        for fp in dead:
            self.gc.note_audit(self.shard, fp, now)
        if dead:
            self.cm.purge(dead)

    def has_chunk(self, fp: Fingerprint) -> bool:
        return fp in self.chunk_store

    def cit_entry(self, fp: Fingerprint) -> CITEntry | None:
        return self.shard.cit_lookup(fp)

    # ----------------------------------------------------------------- local
    def _disk_write(self, fp: Fingerprint, data: bytes) -> None:
        self.chunk_store[fp] = data
        self.stats.disk_bytes_written += len(data)
        self.stats.chunk_writes += 1

    def tick(self, now: int) -> None:
        if self.alive:
            self.cm.drain(
                self.shard, now, on_flip=lambda fp: self._mark_chunk_dirty(fp, now)
            )

    def run_gc(self, now: int) -> list[Fingerprint]:
        if not self.alive:
            return []
        removed = self.gc.run(self.shard, self.chunk_store, now)
        for fp in removed:
            self._mark_chunk_dirty(fp, now)
        return removed

    def stored_bytes(self) -> int:
        return sum(len(v) for v in self.chunk_store.values())


class NodeDown(RuntimeError):
    def __init__(self, node_id: str):
        super().__init__(f"storage node {node_id} is down")
        self.node_id = node_id


class ChunkMissing(RuntimeError):
    def __init__(self, node_id: str, fp: Fingerprint):
        super().__init__(f"chunk {fp} missing on {node_id}")
        self.node_id, self.fp = node_id, fp


class ChunkCorrupt(RuntimeError):
    def __init__(self, node_id: str, fp: Fingerprint):
        super().__init__(f"chunk {fp} corrupt on {node_id}")
        self.node_id, self.fp = node_id, fp
