"""Framework-integration benchmark: dedup checkpointing win across saves.

Not in the paper (it predates large-model training), but this is the table
that justifies the technique inside THIS framework: bytes moved & stored for
repeated checkpoints with/without cluster-wide dedup."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointConfig, DedupCheckpointer
from repro.configs import get_config
from repro.core import ChunkingSpec, DedupCluster, NoDedupCluster
from repro.models import build_model


def run(rows_out: list[str]) -> None:
    cfg = get_config("qwen2.5-32b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # simulate a training run where only 25% of tensors change per save
    # (optimizer slots for frozen layers, embeddings under sparse updates...)
    leaves, treedef = jax.tree.flatten(params)

    def mutate(ls, step):
        out = []
        for i, x in enumerate(ls):
            if i % 4 == step % 4 and x.dtype != jnp.int32:
                out.append(x + 1)
            else:
                out.append(x)
        return out

    cluster = DedupCluster.create(4, chunking=ChunkingSpec("fixed", 128 * 1024))
    ck = DedupCheckpointer(cluster, CheckpointConfig())
    t0 = time.perf_counter()
    for step in range(4):
        leaves = mutate(leaves, step)
        ck.save(f"s{step}", jax.tree.unflatten(treedef, leaves))
    dt = (time.perf_counter() - t0) / 4
    logical = cluster.stats.logical_bytes_written
    unique = cluster.unique_bytes_stored()
    rows_out.append(
        f"ckpt_dedup_4saves,{dt*1e6:.0f},"
        f"savings={100*cluster.space_savings():.0f}%;"
        f"ref_only_leaves={ck.stats['leaves_ref_only']};"
        f"bytes_sent_MB={ck.stats['bytes_sent']/1e6:.1f}"
    )
    rows_out.append(
        f"ckpt_nodedup_equivalent,{dt*1e6:.0f},"
        f"stored_MB={logical/1e6:.1f}_vs_dedup_{unique/1e6:.1f}"
    )
