"""Write-path benchmark: vectorized CDC, batch fingerprinting, and the
serial-vs-batched write transaction. Emits ``BENCH_write_path.json`` (repo
root by default) to anchor the perf trajectory of the host write path.

Numbers on the seed (pre-vectorization): host CDC ~0.11 MB/s — the scalar
reference is re-measured here on a small sample for an honest speedup ratio.

Usage:
    PYTHONPATH=src python benchmarks/write_path_bench.py [--quick] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

from repro.core import (
    ChunkingSpec,
    DedupCluster,
    RepairDaemon,
    WriteError,
    fingerprint_many,
    partition,
    reliable,
)
from repro.core.chunking import chunk_cdc, chunk_cdc_scalar, chunk_object

sys.path.insert(0, str(Path(__file__).resolve().parent))
from simtime import modeled_time_clusterwide, per_edge_maxima  # noqa: E402

MB = 1024 * 1024


def _best(fn, reps: int = 3):
    """Best-of-reps wall time after one warmup; returns (seconds, last result)."""
    r = fn()  # warmup
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        r = fn()
        best = min(best, time.perf_counter() - t0)
    return best, r


def bench_cdc(buf_bytes: int, scalar_bytes: int) -> dict:
    rng = np.random.default_rng(7)
    big = rng.bytes(buf_bytes)
    spec = ChunkingSpec("cdc", 512 * 1024)
    t_vec, _ = _best(lambda: list(chunk_cdc(big, spec)))
    # scalar oracle on a small sample with a small target so it does real
    # per-byte work (a 512K target skips min_size=128K of every chunk)
    small = big[:scalar_bytes]
    small_spec = ChunkingSpec("cdc", 16 * 1024)
    t_scalar, _ = _best(lambda: list(chunk_cdc_scalar(small, small_spec)), reps=1)
    t_vec_small, _ = _best(lambda: list(chunk_cdc(small, small_spec)))
    return {
        "buf_mib": buf_bytes / MB,
        "vectorized_mb_s": buf_bytes / t_vec / 1e6,
        "scalar_mb_s": scalar_bytes / t_scalar / 1e6,
        "vectorized_mb_s_same_input": scalar_bytes / t_vec_small / 1e6,
        "speedup_same_input": t_scalar / t_vec_small,
        "n_chunks": len(list(chunk_cdc(big, spec))),
    }


def bench_fingerprint(buf_bytes: int) -> dict:
    rng = np.random.default_rng(8)
    data = rng.bytes(buf_bytes)
    chunks = chunk_object(data, ChunkingSpec("fixed", 512 * 1024))
    t, _ = _best(lambda: fingerprint_many(chunks))
    return {
        "buf_mib": buf_bytes / MB,
        "n_chunks": len(chunks),
        "mb_s": buf_bytes / t / 1e6,
        "chunks_per_s": len(chunks) / t,
    }


def bench_device_cdc(buf_bytes: int) -> dict:
    """Fused device CDC + fingerprint pipeline: one CDC launch + one
    fingerprint launch for a whole wave of tensor byte streams (the
    checkpoint save shape). ``fused_mb_s`` is wall-clock (NOT gated);
    ``n_chunks``, ``boundary_checksum`` (u32 sum of all inclusive cut
    offsets) and the launches-per-save counters are exact functions of the
    seeded wave + ChunkingSpec — any drift means the kernel's cut selection
    or the fusion contract changed, and the bench gate holds them at
    tolerance 0."""
    import jax
    import jax.numpy as jnp

    from repro.checkpoint import CheckpointConfig, DedupCheckpointer
    from repro.core.chunking import cdc_mask
    from repro.kernels import ops as kops

    rng = np.random.default_rng(15)
    # uneven wave: one dominant leaf + small stragglers, like a real pytree
    weights = [8, 4, 2, 1, 1]
    sizes = [max(1, buf_bytes * w // sum(weights)) for w in weights]
    streams = [
        jnp.asarray(rng.integers(0, 256, size=s, dtype=np.uint8)) for s in sizes
    ]
    target, mn, mx = 8 * 1024, 4 * 1024, 16 * 1024

    def run():
        res = kops.cdc_cut_and_fingerprint_many(
            streams, mask=cdc_mask(target), min_size=mn, max_size=mx
        )
        jax.block_until_ready([r[2] for r in res])
        return res

    t, res = _best(run)
    n_chunks = 0
    checksum = np.uint64(0)
    for cutpos, n_cuts, _, nc in res:
        n_chunks += int(nc)
        cp = np.asarray(jax.device_get(cutpos))[: int(n_cuts)].astype(np.uint64)
        checksum = (checksum + cp.sum(dtype=np.uint64)) % np.uint64(1 << 32)
    # launches-per-save through the checkpointer (the contract the fusion
    # exists for: whole pytree, one launch pair)
    cluster = DedupCluster.create(4, chunking=ChunkingSpec("fixed", 64 * 1024))
    ckpt = DedupCheckpointer(
        cluster, CheckpointConfig(fp_chunk_bytes=target, device_cdc=True)
    )
    ckpt.save("bench", {f"leaf{i}": s for i, s in enumerate(streams)})
    return {
        "buf_mib": buf_bytes / MB,
        "n_streams": len(streams),
        "fused_mb_s": buf_bytes / t / 1e6,
        "n_chunks": n_chunks,
        "boundary_checksum": int(checksum),
        "cdc_launches_per_save": ckpt.stats["cdc_launches"],
        "fp_launches_per_save": ckpt.stats["fp_launches"],
    }


def bench_write_path(n_objects: int, obj_bytes: int) -> dict:
    rng = np.random.default_rng(9)
    # ~50% duplicate content so the dedup path is exercised
    pool = [rng.bytes(obj_bytes) for _ in range(max(2, n_objects // 2))]
    items = [(f"o{i}", pool[i % len(pool)]) for i in range(n_objects)]
    spec = ChunkingSpec("cdc", 8 * 1024)

    def serial():
        # chunk-granular messaging (the pre-batching transaction shape)
        c = DedupCluster.create(8, chunking=spec, batch_unicasts=False)
        for name, data in items:
            c.write_object(name, data)
        return c

    def batched():
        # per-object node batching (the PR 1 message shape)
        c = DedupCluster.create(8, chunking=spec, coalesce_batches=False)
        c.write_objects(list(items))
        return c

    def coalesced():
        # cross-object coalescing: one ChunkOpBatch per node for the whole
        # batch; intra-batch duplicate chunks ride ref-only ops
        c = DedupCluster.create(8, chunking=spec)
        c.write_objects(list(items))
        return c

    # Interleaved best-of-4: the three variants differ by ~10% wall time on
    # top of identical chunking+fingerprint work, so round-robin the reps to
    # expose each variant to the same scheduler noise and take per-variant
    # minima.
    variants = {"serial": serial, "batched": batched, "coalesced": coalesced}
    best = {k: float("inf") for k in variants}
    result = {}
    for k, fn in variants.items():
        result[k] = fn()  # warmup
    for _ in range(4):
        for k, fn in variants.items():
            t0 = time.perf_counter()
            result[k] = fn()
            best[k] = min(best[k], time.perf_counter() - t0)
    t_serial, cs = best["serial"], result["serial"]
    t_batched, cb = best["batched"], result["batched"]
    t_coalesced, cc = best["coalesced"], result["coalesced"]
    for other in (cb, cc):
        assert cs.dedup_ratio() == other.dedup_ratio(), "dedup ratio must match serial"
        assert cs.unique_bytes_stored() == other.unique_bytes_stored()
    snap_s, snap_b, snap_c = (
        cs.stats.snapshot(), cb.stats.snapshot(), cc.stats.snapshot()
    )
    assert snap_c["control_msgs"] < snap_b["control_msgs"]
    assert snap_c["net_bytes"] <= snap_b["net_bytes"]
    return {
        "n_objects": n_objects,
        "obj_kib": obj_bytes / 1024,
        "serial_objects_s": n_objects / t_serial,
        "batched_objects_s": n_objects / t_batched,
        "coalesced_objects_s": n_objects / t_coalesced,
        "speedup": t_serial / t_batched,
        "coalesced_speedup": t_serial / t_coalesced,
        "dedup_ratio": cc.dedup_ratio(),
        "control_msgs_serial": snap_s["control_msgs"],
        "control_msgs_batched": snap_b["control_msgs"],
        "control_msgs_coalesced": snap_c["control_msgs"],
        "chunk_msgs_serial": cs.transport.msgs_by_type.get("chunk_op_batch", 0),
        "chunk_msgs_batched": cb.transport.msgs_by_type.get("chunk_op_batch", 0),
        "chunk_msgs_coalesced": cc.transport.msgs_by_type.get("chunk_op_batch", 0),
        "net_bytes_batched": snap_b["net_bytes"],
        "net_bytes_coalesced": snap_c["net_bytes"],
        # at-least-once accounting: every delivery acked; reliable run -> 0 retries
        "ack_bytes_coalesced": snap_c["ack_bytes"],
        "retransmits_coalesced": snap_c["retransmits"],
    }


def bench_write_cache(n_objects: int, obj_bytes: int) -> dict:
    """Presence-cache probe elision at ~50% duplicate content, cache on vs
    off. Two batches through one session: batch 2 rewrites batch 1's
    content pool under new names, so every batch-2 chunk is a cross-batch
    repeat only the presence cache can turn into a presence-asserted
    ref-only op. Both runs stream in bounded waves, so intra-batch repeats
    are ref-only via the wave-local first-writer set either way — the
    lookup/elision delta isolates the cache's contribution. Every column
    except the throughput one is a deterministic function of the workload
    and the wire model — the bench gate holds them at tolerance 0."""
    rng = np.random.default_rng(9)
    pool = [rng.bytes(obj_bytes) for _ in range(max(2, n_objects // 2))]
    batch1 = [(f"a{i}", pool[i % len(pool)]) for i in range(n_objects)]
    batch2 = [(f"b{i}", pool[i % len(pool)]) for i in range(n_objects)]
    spec = ChunkingSpec("cdc", 8 * 1024)
    wave = max(4 * obj_bytes, 64 * 1024)

    def run(presence):
        c = DedupCluster.create(8, chunking=spec)
        s = c.client(presence_cache=presence, wave_bytes=wave)
        s.put_many(list(batch1))
        s.put_many(list(batch2))
        return c

    c_off = run(0)  # warmup is also the cache-off reference
    t_on, c_on = _best(lambda: run(4096))
    off, on = c_off.stats.snapshot(), c_on.stats.snapshot()
    assert c_off.dedup_ratio() == c_on.dedup_ratio(), (
        "presence elision must not change what is stored"
    )
    assert on["probe_elisions"] > 0
    assert on["lookup_unicasts"] < off["lookup_unicasts"], (
        "cache-on must carry strictly fewer CIT probes"
    )
    assert (
        on["lookup_unicasts"] + on["probe_elisions"] == off["lookup_unicasts"]
    ), "every elision accounts for exactly one skipped probe"
    assert on["presence_fallbacks"] == 0, "no invalidations here -> no fallbacks"
    return {
        "n_objects": 2 * n_objects,
        "obj_kib": obj_bytes / 1024,
        "cache_on_objects_s": 2 * n_objects / t_on,  # wall clock; NOT gated
        "dedup_ratio": c_on.dedup_ratio(),
        "lookups_cache_off": off["lookup_unicasts"],
        "lookups_cache_on": on["lookup_unicasts"],
        "probe_elisions": on["probe_elisions"],
        "elision_rate": on["probe_elisions"] / off["lookup_unicasts"],
        "cache_hits": on["cache_hits"],
        "cache_evictions": on["cache_evictions"],
        "control_msgs_cache_off": off["control_msgs"],
        "control_msgs_cache_on": on["control_msgs"],
        "net_bytes_cache_off": off["net_bytes"],
        "net_bytes_cache_on": on["net_bytes"],
        "presence_fallbacks": on["presence_fallbacks"],
        "peak_dirty_bytes_cache_on": on["peak_dirty_bytes"],
        "wave_bytes": wave,
    }


def bench_read_path(n_objects: int, obj_bytes: int) -> dict:
    """Coalesced batch restore vs the serial read oracle on the
    write-cache bench's ~50%-dup two-batch workload (batch b re-stores
    batch a's content pool under new names, so the restore batch shares
    chunks across objects). The batched engine must return byte-identical
    data with >= 3x fewer read messages while fetching every distinct
    chunk of the batch exactly once: its read payload equals the
    cluster's unique stored bytes, where the serial oracle pays for every
    recipe reference (the fetch_elisions delta). The fragmentation
    columns measure how wide dedup scatters one logical object across
    nodes — the restore-cost baseline ROADMAP item 5's placement work is
    judged against. Every column except the two *_objects_s wall-clock
    ones is a deterministic function of the workload and the wire model —
    the bench gate holds them at tolerance 0."""
    rng = np.random.default_rng(9)
    pool = [rng.bytes(obj_bytes) for _ in range(max(2, n_objects // 2))]
    items = [(f"a{i}", pool[i % len(pool)]) for i in range(n_objects)]
    items += [(f"b{i}", pool[i % len(pool)]) for i in range(n_objects)]
    names = [n for n, _ in items]
    spec = ChunkingSpec("cdc", 8 * 1024)

    def populate():
        c = DedupCluster.create(8, chunking=spec)
        c.write_objects(list(items))
        c.tick(2)
        return c

    def read(c, batched):
        c.batch_reads = batched
        frag: list = []
        m0, n0, a0 = c.stats.control_msgs, c.stats.net_bytes, c.stats.ack_bytes
        t0 = time.perf_counter()
        if batched:
            data = c.read_objects(names, frag_out=frag)
        else:
            data = [c.read_object(n) for n in names]
        wall = time.perf_counter() - t0
        msgs = c.stats.control_msgs - m0
        # net_bytes carries payload + acks (control headers are wire_bytes),
        # and read requests are payload-free, so this is the response payload
        payload = (c.stats.net_bytes - n0) - (c.stats.ack_bytes - a0)
        return data, msgs, c.stats.net_bytes - n0, payload, wall, frag

    cs, cb = populate(), populate()
    oracle, msgs_serial, net_serial, payload_serial, t_serial, _ = read(cs, False)
    got, msgs_batched, net_batched, payload_batched, t_batched, frag = read(cb, True)
    assert got == oracle == [d for _, d in items], (
        "batched restore must be byte-identical to the serial oracle"
    )
    assert msgs_serial >= 3 * msgs_batched, "read messages must drop >= 3x"
    assert cb.stats.fetch_elisions > 0
    assert payload_batched == cb.unique_bytes_stored(), (
        "each distinct chunk of the batch must travel exactly once"
    )
    assert payload_serial == sum(len(d) for _, d in items), (
        "the serial oracle fetches every recipe reference"
    )
    return {
        "n_objects": 2 * n_objects,
        "obj_kib": obj_bytes / 1024,
        "serial_objects_s": 2 * n_objects / t_serial,    # wall; NOT gated
        "batched_objects_s": 2 * n_objects / t_batched,  # wall; NOT gated
        "read_msgs_serial": msgs_serial,
        "read_msgs_batched": msgs_batched,
        "msg_reduction": msgs_serial / msgs_batched,
        "read_net_bytes_serial": net_serial,
        "read_net_bytes_batched": net_batched,
        "read_payload_serial": payload_serial,
        "read_payload_batched": payload_batched,
        "read_batches": cb.stats.read_batches,
        "read_fallback_rounds": cb.stats.read_fallback_rounds,
        "fetch_elisions": cb.stats.fetch_elisions,
        # restore fragmentation: how wide one logical object scatters
        "frag_chunks_total": sum(f["chunks"] for f in frag),
        "frag_nodes_touched_total": sum(f["nodes"] for f in frag),
        "frag_nodes_touched_max": max(f["nodes"] for f in frag),
        "frag_spread_max": max(f["max_chunks_one_node"] for f in frag),
        # per-edge modeled time of each cluster's full run (same writes,
        # different read shape): the delta is the read path's modeled win
        "modeled_time_per_edge_serial_s": modeled_time_clusterwide(
            cs, link_model="per_edge"
        ),
        "modeled_time_per_edge_batched_s": modeled_time_clusterwide(
            cb, link_model="per_edge"
        ),
    }


def bench_recovery(n_objects: int, obj_bytes: int) -> dict:
    """Recovery-round cost model on a fixed split-brain schedule: writes
    across an open partition, heal, client retries, then the full
    digest-repair + refcount-audit + GC round. Every column except the
    wall-clock one is a deterministic function of the workload and the
    wire model — the bench gate holds them at tolerance 0."""
    rng = np.random.default_rng(11)
    spec = ChunkingSpec("fixed", 2048)
    c = DedupCluster.create(6, replicas=2, chunking=spec)
    c.write_objects([(f"base{i}", rng.bytes(obj_bytes)) for i in range(n_objects)])
    c.tick(3)
    c.transport.policy = partition(
        ("oss0", "oss1", "oss2"), ("oss3", "oss4", "oss5")
    )
    items = [(f"w{i}", rng.bytes(obj_bytes)) for i in range(n_objects)]
    failed = []
    for name, data in items:
        try:
            c.write_object(name, data)
        except WriteError:
            failed.append((name, data))
    c.transport.policy = reliable()
    for name, data in failed:
        c.write_object(name, data)
    net_before, msgs_before = c.stats.net_bytes, c.stats.control_msgs
    t0 = time.perf_counter()
    report = c.recover()
    wall = time.perf_counter() - t0
    return {
        "n_objects": n_objects,
        "obj_kib": obj_bytes / 1024,
        "writes_failed_during_partition": len(failed),
        "digest_msgs": c.transport.msgs_by_type.get("digest_request", 0),
        "repair_msgs": c.transport.msgs_by_type.get("repair_chunk", 0),
        "audit_msgs": report.audit_msgs,
        "omap_repaired": report.omap_repaired,
        "chunks_repaired": report.chunks_repaired,
        "cit_repaired": report.cit_repaired,
        "repair_bytes": report.repair_bytes,
        "refs_over": report.refs_over,
        "refs_under": report.refs_under,
        "flags_flipped": report.flags_flipped,
        "gc_removed": report.gc_removed,
        "recovery_net_bytes": c.stats.net_bytes - net_before,
        "recovery_msgs": c.stats.control_msgs - msgs_before,
        # both link models pinned: the legacy uniform n-way split and the
        # per-edge straggler-NIC bottleneck (the default)
        "modeled_time_uniform_s": modeled_time_clusterwide(c, link_model="uniform"),
        "modeled_time_per_edge_s": modeled_time_clusterwide(c, link_model="per_edge"),
        "recovery_wall_s": wall,  # noisy; NOT gated
    }


def bench_always_on(n_objects: int, obj_bytes: int) -> dict:
    """Always-on recovery cost model: tombstone wire traffic and the
    incremental epoch-scoped digest scope. A cold ``RepairDaemon`` round
    digests every placement group; after a small steady-state mutation
    window (one rewrite + one delete) the next round re-digests strictly
    fewer groups — the claim the asserts pin and the gated columns
    quantify. A third round past the GC horizon reaps the delete's
    tombstone. Every column is a deterministic function of the workload
    and the wire model — the bench gate holds them at tolerance 0."""
    rng = np.random.default_rng(13)
    spec = ChunkingSpec("fixed", 2048)
    c = DedupCluster.create(6, replicas=2, chunking=spec)
    c.write_objects([(f"o{i}", rng.bytes(obj_bytes)) for i in range(n_objects)])
    c.tick(3)
    daemon = RepairDaemon(c)
    r_cold = daemon.step()  # cold start: unknown past, every group digested
    # steady state: a small mutation window, then an incremental round
    c.write_object("o1", rng.bytes(obj_bytes))
    c.delete_object("o2")
    c.tick(1)
    net_before, msgs_before = c.stats.net_bytes, c.stats.control_msgs
    r_incr = daemon.step()
    incr_net = c.stats.net_bytes - net_before
    incr_msgs = c.stats.control_msgs - msgs_before
    assert r_incr.groups_skipped > 0, "clean groups must be skipped"
    assert r_incr.groups_digested < r_cold.groups_digested, (
        "an incremental round must re-digest strictly fewer groups"
    )
    # age the tombstone past the GC horizon; the next round reaps it
    c.tick(31)
    r_reap = daemon.step()
    assert r_reap.tombstones_reaped > 0, "aged full-acked tombstone must reap"
    return {
        "n_objects": n_objects,
        "obj_kib": obj_bytes / 1024,
        "cold_groups_digested": r_cold.groups_digested,
        "incr_groups_digested": r_incr.groups_digested,
        "incr_groups_skipped": r_incr.groups_skipped,
        "incr_round_net_bytes": incr_net,
        "incr_round_msgs": incr_msgs,
        "tombstone_commit_msgs": c.transport.msgs_by_type.get("omap_delete", 0),
        "tombstone_reap_msgs": c.transport.msgs_by_type.get("tombstone_reap", 0),
        "tombstones_reaped": r_reap.tombstones_reaped,
        "audit_deferred": (
            r_cold.audit_deferred + r_incr.audit_deferred + r_reap.audit_deferred
        ),
    }


def bench_multi_tenant(n_clients: int, n_objects: int, ops_per_client: int) -> dict:
    """Multi-tenant scheduled workload (core/workload.py over the
    discrete-event Scheduler): N concurrent client sessions, Zipf names
    and sizes, mixed put/get/delete, bursty seeded arrivals. Every column
    is a deterministic function of the spec seed — the bench gate holds
    them at tolerance 0. The asserts pin the interleaving claims the
    refactor exists for: >= 2 sessions with sent-but-uncommitted waves at
    one tick, and wave k+1 chunking overlapping wave k in flight.

    The seen-window sizing study rides along: the same spec at 2/4/8
    clients, recording peak window occupancy per in-flight depth. These
    measured margins replace the chaos suites' old fixed 25%-of-capacity
    assertion (tests/conftest.py keeps only the zero-eviction claim)."""
    from repro.core import Scheduler, WorkloadSpec, run_workload

    spec_of = lambda nc: WorkloadSpec(  # noqa: E731
        clients=nc, objects=n_objects, ops_per_client=ops_per_client,
        seed=5, bulk_first=2, wave_bytes=8192, presence_cache=32,
    )

    # sizing study first (small sweeps), headline 8-client run last so the
    # contention columns come from the full-width cluster
    window_capacity = 1024
    sweep: dict[int, int] = {}
    for nc in (2, 4):
        cs = DedupCluster.create(4, replicas=2, chunking=ChunkingSpec("fixed", 2048))
        run_workload(cs, spec_of(nc))
        assert cs.stats.seen_evictions == 0, "sizing sweep must not evict"
        sweep[nc] = cs.stats.seen_high_water

    c = DedupCluster.create(4, replicas=2, chunking=ChunkingSpec("fixed", 2048))
    sched = Scheduler(c, seed=5)
    t0 = time.perf_counter()
    rep = run_workload(c, spec_of(n_clients), scheduler=sched)
    wall = time.perf_counter() - t0
    assert c.stats.seen_evictions == 0, "sizing sweep must not evict"
    sweep[n_clients] = c.stats.seen_high_water
    assert rep["max_in_flight_sessions"] >= 2, (
        "scheduler must interleave >= 2 sessions"
    )
    assert c.stats.waves_overlapped >= 1, "wave pipelining must overlap"
    edges = per_edge_maxima(c)
    totals = rep["totals"]
    return {
        "clients": n_clients,
        "objects": n_objects,
        "ops_per_client": ops_per_client,
        "ops_total": totals["ops"],
        "puts_ok": totals["puts_ok"],
        "gets_ok": totals["gets_ok"],
        "deletes_ok": totals["deletes_ok"],
        "not_found": totals["not_found"],
        "failures": totals["failures"],
        "bytes_written": totals["bytes_written"],
        "latency_p50_ticks": totals["latency_p50_ticks"],
        "latency_p99_ticks": totals["latency_p99_ticks"],
        "elapsed_ticks": rep["elapsed_ticks"],
        "scheduler_steps": rep["scheduler_steps"],
        "max_in_flight_sessions": rep["max_in_flight_sessions"],
        "waves_overlapped": c.stats.waves_overlapped,
        "writes_superseded": c.stats.writes_superseded,
        "probe_elisions": c.stats.probe_elisions,
        "cache_hits": c.stats.cache_hits,
        "net_bytes": c.stats.net_bytes,
        "control_msgs": c.stats.control_msgs,
        "busiest_edge": edges["busiest_edge"],
        "busiest_edge_payload": edges["busiest_edge_payload"],
        "node_ingress_max": edges["node_ingress_max"],
        "node_egress_max": edges["node_egress_max"],
        "seen_window_capacity": window_capacity,
        "seen_high_water_c2": sweep[2],
        "seen_high_water_c4": sweep[4],
        "seen_high_water_c8": sweep[n_clients],
        # measured margin (percent of capacity) at full client width — the
        # number the old fixed 25% assertion guessed at
        "seen_margin_pct_c8": sweep[n_clients] * 100 // window_capacity,
        "modeled_time_uniform_s": modeled_time_clusterwide(c, link_model="uniform"),
        "modeled_time_per_edge_s": modeled_time_clusterwide(c, link_model="per_edge"),
        "workload_wall_s": wall,  # noisy; NOT gated
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="small inputs (CI smoke)")
    ap.add_argument("--out", type=Path, default=None)
    args = ap.parse_args()

    if args.quick:
        cdc_bytes, scalar_bytes = 1 * MB, 64 * 1024
        fp_bytes = 4 * MB
        dev_cdc_bytes = 256 * 1024
        n_objects, obj_bytes = 40, 32 * 1024
        rec_objects, rec_bytes = 16, 8 * 1024
        mt_objects, mt_ops = 24, 8
    else:
        cdc_bytes, scalar_bytes = 8 * MB, 256 * 1024
        fp_bytes = 32 * MB
        dev_cdc_bytes = 2 * MB
        n_objects, obj_bytes = 200, 64 * 1024
        rec_objects, rec_bytes = 48, 16 * 1024
        mt_objects, mt_ops = 64, 20

    report = {
        "quick": args.quick,
        "cdc": bench_cdc(cdc_bytes, scalar_bytes),
        "device_cdc": bench_device_cdc(dev_cdc_bytes),
        "fingerprint": bench_fingerprint(fp_bytes),
        "write_path": bench_write_path(n_objects, obj_bytes),
        "write_cache": bench_write_cache(n_objects, obj_bytes),
        "read_path": bench_read_path(n_objects, obj_bytes),
        "recovery": bench_recovery(rec_objects, rec_bytes),
        "always_on": bench_always_on(rec_objects, rec_bytes),
        "multi_tenant": bench_multi_tenant(8, mt_objects, mt_ops),
    }
    out = args.out or Path(__file__).resolve().parent.parent / "BENCH_write_path.json"
    out.write_text(json.dumps(report, indent=2) + "\n")
    print(json.dumps(report, indent=2))
    print(f"\nwrote {out}")


if __name__ == "__main__":
    main()
