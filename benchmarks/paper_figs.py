"""Paper reproduction benchmarks — one function per table/figure.

Each returns a list of CSV rows (name, us_per_call, derived). `derived`
carries the figure's y-axis (modeled MB/s or savings %). Workloads are
scaled-down FIO equivalents (exact op/byte accounting, modeled time —
see simtime.py and DESIGN.md §6.4).
"""

from __future__ import annotations

import time

from repro.core import (
    CentralDedupCluster,
    ChunkingSpec,
    DedupCluster,
    DiskLocalDedupCluster,
    NoDedupCluster,
)
from repro.data import DedupWorkload, make_dedup_objects

from benchmarks import simtime as ST

MB = 1024 * 1024


def _run_writes(cluster, objs):
    t0 = time.perf_counter()
    for name, data in objs:
        cluster.write_object(name, data)
    if hasattr(cluster, "tick"):
        cluster.tick(2)
    return time.perf_counter() - t0


# ---------------------------------------------------------------- Fig 4(a) --
def fig4a_chunk_size(rows_out: list[str]) -> None:
    """Bandwidth vs chunk size at 0% dedup, 8 client threads."""
    for chunk_kb in [64, 128, 256, 512, 1024]:
        w = DedupWorkload(object_size=1 * MB, n_objects=48, dedup_pct=0.0,
                          block_size=4096, seed=1)
        objs = make_dedup_objects(w)
        logical = sum(len(d) for _, d in objs)
        ch = ChunkingSpec("fixed", chunk_kb * 1024)

        base = NoDedupCluster.create(4)
        wall_b = _run_writes(base, objs)
        t_base = ST.modeled_time_nodedup(base)

        cw = DedupCluster.create(4, chunking=ch)
        wall_c = _run_writes(cw, objs)
        t_cw = ST.modeled_time_clusterwide(cw)

        ce = CentralDedupCluster.create(4, chunking=ch)
        wall_e = _run_writes(ce, objs)
        t_ce = ST.modeled_time_central(ce)

        rows_out.append(f"fig4a_baseline_{chunk_kb}KB,{wall_b*1e6/len(objs):.1f},"
                        f"modeled_MBps={ST.mbps(logical, t_base):.0f}")
        rows_out.append(f"fig4a_clusterwide_{chunk_kb}KB,{wall_c*1e6/len(objs):.1f},"
                        f"modeled_MBps={ST.mbps(logical, t_cw):.0f}")
        rows_out.append(f"fig4a_central_{chunk_kb}KB,{wall_e*1e6/len(objs):.1f},"
                        f"modeled_MBps={ST.mbps(logical, t_ce):.0f}")


# ---------------------------------------------------------------- Fig 4(b) --
def fig4b_dedup_ratio(rows_out: list[str]) -> None:
    """Bandwidth vs dedup percentage at 512 KB chunks, 8 threads."""
    for pct in [0, 25, 50, 75, 100]:
        w = DedupWorkload(object_size=1 * MB, n_objects=48, dedup_pct=float(pct),
                          block_size=512 * 1024, pool_blocks=8, seed=2)
        objs = make_dedup_objects(w)
        logical = sum(len(d) for _, d in objs)
        ch = ChunkingSpec("fixed", 512 * 1024)

        cw = DedupCluster.create(4, chunking=ch)
        wall_c = _run_writes(cw, objs)
        t_cw = ST.modeled_time_clusterwide(cw)

        ce = CentralDedupCluster.create(4, chunking=ch)
        wall_e = _run_writes(ce, objs)
        t_ce = ST.modeled_time_central(ce)

        rows_out.append(
            f"fig4b_clusterwide_dedup{pct},{wall_c*1e6/len(objs):.1f},"
            f"modeled_MBps={ST.mbps(logical, t_cw):.0f};savings={100*cw.space_savings():.0f}%")
        rows_out.append(
            f"fig4b_central_dedup{pct},{wall_e*1e6/len(objs):.1f},"
            f"modeled_MBps={ST.mbps(logical, t_ce):.0f};savings={100*ce.space_savings():.0f}%")


# ---------------------------------------------------------------- Fig 5(a) --
def fig5a_scalability(rows_out: list[str]) -> None:
    """Bandwidth vs number of client threads (512 KB chunks)."""
    ch = ChunkingSpec("fixed", 512 * 1024)
    for threads in [1, 4, 8, 16, 32]:
        w = DedupWorkload(object_size=1 * MB, n_objects=6 * threads, dedup_pct=25.0,
                          block_size=512 * 1024, pool_blocks=8, seed=3)
        objs = make_dedup_objects(w)
        logical = sum(len(d) for _, d in objs)

        cw = DedupCluster.create(4, chunking=ch)
        wall_c = _run_writes(cw, objs)
        t_cw = ST.modeled_time_clusterwide(cw)

        ce = CentralDedupCluster.create(4, chunking=ch)
        wall_e = _run_writes(ce, objs)
        t_ce = ST.modeled_time_central(ce, n_clients=threads)

        rows_out.append(f"fig5a_clusterwide_{threads}cl,{wall_c*1e6/len(objs):.1f},"
                        f"modeled_MBps={ST.mbps(logical, t_cw):.0f}")
        rows_out.append(f"fig5a_central_{threads}cl,{wall_e*1e6/len(objs):.1f},"
                        f"modeled_MBps={ST.mbps(logical, t_ce):.0f}")


# ---------------------------------------------------------------- Fig 5(b) --
def fig5b_consistency_variants(rows_out: list[str]) -> None:
    """Async tagged consistency vs sync chunk-flag vs sync object-flag."""
    tb = ST.DEFAULT
    for chunk_kb in [128, 256, 512, 1024]:
        w = DedupWorkload(object_size=1 * MB, n_objects=48, dedup_pct=0.0,
                          block_size=4096, seed=4)
        objs = make_dedup_objects(w)
        logical = sum(len(d) for _, d in objs)
        ch = ChunkingSpec("fixed", chunk_kb * 1024)

        cw = DedupCluster.create(4, chunking=ch)
        wall = _run_writes(cw, objs)
        n_chunks = sum(nd.stats.chunk_writes for nd in cw.nodes.values())

        t_async = ST.modeled_time_clusterwide(cw)                      # flags async: free
        t_obj = ST.modeled_time_clusterwide(cw, extra_serial_s=len(objs) * tb.flag_io_s)
        t_chunk = ST.modeled_time_clusterwide(cw, extra_serial_s=n_chunks * tb.flag_io_s)

        rows_out.append(f"fig5b_async_{chunk_kb}KB,{wall*1e6/len(objs):.1f},"
                        f"modeled_MBps={ST.mbps(logical, t_async):.0f}")
        rows_out.append(f"fig5b_objectsync_{chunk_kb}KB,{wall*1e6/len(objs):.1f},"
                        f"modeled_MBps={ST.mbps(logical, t_obj):.0f}")
        rows_out.append(f"fig5b_chunksync_{chunk_kb}KB,{wall*1e6/len(objs):.1f},"
                        f"modeled_MBps={ST.mbps(logical, t_chunk):.0f}")


# ----------------------------------------------- beyond-paper: fp-first ----
def fp_first_network(rows_out: list[str]) -> None:
    """Beyond-paper optimization: probe the CIT with a 64 B fingerprint
    before shipping chunk bytes. The paper always ships bytes (its Fig 4b
    explanation); fp-first trades one RTT for dedup_pct of the network."""
    w = DedupWorkload(object_size=1 * MB, n_objects=32, dedup_pct=75.0,
                      block_size=512 * 1024, pool_blocks=8, seed=9)
    objs = make_dedup_objects(w)
    ch = ChunkingSpec("fixed", 512 * 1024)
    for fp_first in (False, True):
        c = DedupCluster.create(4, chunking=ch, send_fingerprint_first=fp_first)
        wall = _run_writes(c, objs)
        name = "fpfirst" if fp_first else "shipbytes"
        rows_out.append(
            f"netopt_{name},{wall*1e6/len(objs):.1f},"
            f"net_MB={c.stats.net_bytes/1e6:.1f};savings={100*c.space_savings():.0f}%")


# ----------------------------------------------------------------- Table 2 --
def table2_space_savings(rows_out: list[str]) -> None:
    """Space savings (%) vs number of disks, 100% dedup ratio."""
    for n_disks in [1, 2, 4, 8]:
        # pool sized so cluster-wide savings land at the paper's ~85%
        w = DedupWorkload(object_size=256 * 1024, n_objects=96, dedup_pct=100.0,
                          block_size=4096, pool_blocks=900, seed=5)
        objs = make_dedup_objects(w)
        ch = ChunkingSpec("fixed", 4096)

        cw = DedupCluster.create(n_disks, chunking=ch)
        wall_c = _run_writes(cw, objs)
        dl = DiskLocalDedupCluster.create(n_disks, chunking=ch)
        wall_d = _run_writes(dl, objs)

        rows_out.append(f"table2_clusterwide_{n_disks}d,{wall_c*1e6/len(objs):.1f},"
                        f"savings={100*cw.space_savings():.0f}%")
        rows_out.append(f"table2_disklocal_{n_disks}d,{wall_d*1e6/len(objs):.1f},"
                        f"savings={100*dl.space_savings():.0f}%")
