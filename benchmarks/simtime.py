"""Modeled-time cost model for the paper-reproduction benchmarks.

The in-process cluster counts every byte and every metadata op exactly;
wall-clock is *modeled* from the paper's testbed constants (Table 1: 4 OSS,
10 GbE, 2x Samsung 850 PRO per OSS, Xeon E5-2640v4). All benchmark outputs
are labeled `modeled_MBps` — operation counts are exact, time is derived.

Pipeline assumption: network, disk, fingerprint CPU and metadata I/O overlap;
the slowest resource bounds throughput (classic bottleneck analysis).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Testbed:
    net_Bps_per_node: float = 10e9 / 8          # 10 GbE
    disk_Bps_per_node: float = 2 * 520e6        # 2x SATA SSD per OSS
    fp_Bps_per_node: float = 1.2e9              # SHA-1/256 on ~3 Xeon cores
    meta_op_s: float = 60e-6                    # SQLite-backed CIT/OMAP op
    flag_io_s: float = 150e-6                   # synchronous flag-switch I/O
    client_overhead_s: float = 1e-3


DEFAULT = Testbed()


def straggler_nic_seconds(cluster, tb: Testbed = DEFAULT) -> float:
    """Per-edge network bottleneck: each node's NIC carries the payload of
    every edge incident to it (full duplex — ingress and egress are
    independent lanes; the binding lane is the larger). The cluster is as
    fast as its most loaded NIC, not the average one — a skewed placement
    or a recovery round hammering one holder shows up here while the
    uniform n-way split hides it. Uses the transport's per-edge accounting
    (``EdgeStats.payload_bytes``, ack bytes included on the reverse edge);
    the external client's NIC is not modeled, matching the uniform model
    which never charged client-side time either."""
    ingress: dict[str, int] = {}
    egress: dict[str, int] = {}
    for (src, dst), e in cluster.transport.edges.items():
        egress[src] = egress.get(src, 0) + e.payload_bytes
        ingress[dst] = ingress.get(dst, 0) + e.payload_bytes
    worst = 0
    for nid in cluster.nodes:
        worst = max(worst, ingress.get(nid, 0), egress.get(nid, 0))
    return worst / tb.net_Bps_per_node


def per_edge_maxima(cluster) -> dict:
    """Deterministic per-edge contention summary for the multi-tenant
    workload report and the ``multi_tenant`` bench columns: the busiest
    edge (by payload bytes) and the busiest node NIC lanes (ingress /
    egress payload maxima over the transport's per-edge accounting —
    the same aggregation ``straggler_nic_seconds`` prices). Only node
    ids count toward NIC lanes, so client endpoints (``client``, ``c0``
    ...) contribute load to nodes without being mistaken for one. Ties
    break on the lexicographically first edge key — deterministic across
    runs and interpreters (edge keys are strings, never hash-ordered)."""
    edges = cluster.transport.edges
    busiest_key, busiest_payload = "", 0
    for key in sorted(edges, key=lambda k: (k[0], k[1])):
        p = edges[key].payload_bytes
        if p > busiest_payload:
            busiest_key, busiest_payload = f"{key[0]}->{key[1]}", p
    ingress: dict[str, int] = {}
    egress: dict[str, int] = {}
    for (src, dst), e in edges.items():
        egress[src] = egress.get(src, 0) + e.payload_bytes
        ingress[dst] = ingress.get(dst, 0) + e.payload_bytes
    return {
        "edges": len(edges),
        "busiest_edge": busiest_key,
        "busiest_edge_payload": busiest_payload,
        "node_ingress_max": max(
            (ingress.get(nid, 0) for nid in cluster.nodes), default=0
        ),
        "node_egress_max": max(
            (egress.get(nid, 0) for nid in cluster.nodes), default=0
        ),
    }


def modeled_time_clusterwide(
    cluster,
    tb: Testbed = DEFAULT,
    extra_serial_s: float = 0.0,
    link_model: str = "per_edge",
) -> float:
    """Bottleneck time for a DedupCluster workload (distributed everything).

    ``net_bytes`` already includes the per-delivery ack bytes of the
    at-least-once transport; retransmissions chasing lost messages/acks add
    metadata ops, and the simulated ticks senders spent waiting on ack
    timeouts are a serial cost (nothing overlaps a sender stalled on a
    retry loop). Under a reliable policy both terms are zero.

    ``link_model`` picks the network term: ``"per_edge"`` (default)
    charges the straggler NIC from the transport's per-edge stats —
    skewed traffic is bound by its hottest link; ``"uniform"`` keeps the
    legacy aggregate/n split (every byte assumed perfectly spread over all
    NICs). Both are pinned in the bench JSON."""
    n = max(1, len(cluster.nodes))
    if link_model == "uniform":
        t_net = cluster.stats.net_bytes / (n * tb.net_Bps_per_node)
    elif link_model == "per_edge":
        t_net = straggler_nic_seconds(cluster, tb)
    else:
        raise ValueError(f"unknown link_model {link_model!r}")
    t_disk = max(
        (nd.stats.disk_bytes_written / tb.disk_Bps_per_node for nd in cluster.nodes.values()),
        default=0.0,
    )
    # chunking+fingerprinting happens on every primary OSS in parallel
    t_cpu = cluster.stats.logical_bytes_written / (n * tb.fp_Bps_per_node)
    retransmits = getattr(cluster.stats, "retransmits", 0)
    ops = cluster.stats.control_msgs + cluster.stats.lookup_unicasts + retransmits
    t_meta = ops * tb.meta_op_s / n
    t_retry = getattr(cluster.stats, "timeout_ticks_waited", 0) * tb.flag_io_s
    return max(t_net, t_disk, t_cpu, t_meta) + t_retry + extra_serial_s + tb.client_overhead_s


def modeled_time_central(cluster, tb: Testbed = DEFAULT, n_clients: int = 8) -> float:
    """Central dedup server: chunking/fingerprinting and every metadata op
    serialize through one machine (the paper's Fig 5a bottleneck). Queueing
    contention grows with concurrent clients (lock convoy / DB thrashing —
    the paper measures collapse to ~200 MB/s at 32 threads)."""
    n = max(1, len(cluster.nodes))
    t_net = cluster.stats.net_bytes / tb.net_Bps_per_node     # server NIC
    t_disk = max(
        (nd.stats.disk_bytes_written / tb.disk_Bps_per_node for nd in cluster.nodes.values()),
        default=0.0,
    )
    contention = 1.0 + 0.09 * max(0, n_clients - 1)
    t_cpu = cluster.central_cpu_bytes / tb.fp_Bps_per_node    # ONE node's cores
    t_meta = cluster.central_ops * tb.meta_op_s               # serialized
    # convoy effect hits the whole serial section (locks + scheduler churn)
    t_serial = max(t_cpu, t_meta) * contention
    return max(t_net, t_disk, t_serial) + tb.client_overhead_s


def modeled_time_nodedup(cluster, tb: Testbed = DEFAULT) -> float:
    n = max(1, len(cluster.nodes))
    t_net = cluster.stats.net_bytes / (n * tb.net_Bps_per_node)
    t_disk = max(
        (nd.stats.disk_bytes_written / tb.disk_Bps_per_node for nd in cluster.nodes.values()),
        default=0.0,
    )
    return max(t_net, t_disk) + tb.client_overhead_s


def mbps(logical_bytes: int, seconds: float) -> float:
    return logical_bytes / max(seconds, 1e-9) / 1e6
