# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import ckpt_bench, kernel_bench, paper_figs

    rows: list[str] = ["name,us_per_call,derived"]
    sections = [
        ("Fig 4(a) bandwidth vs chunk size", paper_figs.fig4a_chunk_size),
        ("Fig 4(b) bandwidth vs dedup ratio", paper_figs.fig4b_dedup_ratio),
        ("Fig 5(a) scalability vs client threads", paper_figs.fig5a_scalability),
        ("Fig 5(b) consistency variants", paper_figs.fig5b_consistency_variants),
        ("Table 2 space savings vs #disks", paper_figs.table2_space_savings),
        ("Beyond-paper: fingerprint-first network", paper_figs.fp_first_network),
        ("Kernel microbench", kernel_bench.run),
        ("Dedup checkpointing", ckpt_bench.run),
    ]
    for title, fn in sections:
        print(f"# --- {title} ---", file=sys.stderr, flush=True)
        fn(rows)
    print("\n".join(rows))


if __name__ == "__main__":
    main()
