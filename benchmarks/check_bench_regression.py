"""Bench regression gate: compare the DETERMINISTIC columns of a fresh
``BENCH_write_path.json`` against a committed baseline.

Message counts (control_msgs_*), byte counts (net_bytes_*), chunk counts
and the dedup ratio are exact functions of the workload and the wire
model — any drift is a real message-shape or accounting change and fails
the job with tolerance 0. Wall-clock columns (*_mb_s, *_objects_s,
speedup*) are explicitly IGNORED: CI boxes are ±20% noisy (see
CHANGES.md), so they carry no gate signal.

Usage:
    python benchmarks/check_bench_regression.py FRESH.json BASELINE.json

Exit 0 when every deterministic column matches, 1 otherwise (with a
per-column diff). When the message shape changes INTENTIONALLY, regenerate
the baseline (``write_path_bench.py --quick --out
benchmarks/bench_baseline_quick.json``) in the same PR and say why.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

# (section, column) pairs that must match exactly. Everything else in the
# report is either derived from these or wall-clock noise.
DETERMINISTIC_COLUMNS = [
    ("cdc", "n_chunks"),
    ("cdc", "buf_mib"),
    # fused device CDC + fingerprint: chunk count, the u32 checksum of all
    # cut offsets and the one-launch-pair-per-save counters are exact
    # functions of the seeded wave — drift means the device cut selection
    # or the fusion contract changed
    ("device_cdc", "buf_mib"),
    ("device_cdc", "n_streams"),
    ("device_cdc", "n_chunks"),
    ("device_cdc", "boundary_checksum"),
    ("device_cdc", "cdc_launches_per_save"),
    ("device_cdc", "fp_launches_per_save"),
    ("fingerprint", "n_chunks"),
    ("fingerprint", "buf_mib"),
    ("write_path", "n_objects"),
    ("write_path", "obj_kib"),
    ("write_path", "dedup_ratio"),
    ("write_path", "control_msgs_serial"),
    ("write_path", "control_msgs_batched"),
    ("write_path", "control_msgs_coalesced"),
    ("write_path", "chunk_msgs_serial"),
    ("write_path", "chunk_msgs_batched"),
    ("write_path", "chunk_msgs_coalesced"),
    ("write_path", "net_bytes_batched"),
    ("write_path", "net_bytes_coalesced"),
    ("write_path", "ack_bytes_coalesced"),
    ("write_path", "retransmits_coalesced"),
    # presence-cache probe elision at 50% dup, cache on vs off: lookup /
    # elision / message / byte counts and the peak dirty-bytes bound are
    # exact functions of the seeded two-batch workload — drift means the
    # elision accounting, the wave shape, or the cache policy changed
    ("write_cache", "n_objects"),
    ("write_cache", "obj_kib"),
    ("write_cache", "dedup_ratio"),
    ("write_cache", "lookups_cache_off"),
    ("write_cache", "lookups_cache_on"),
    ("write_cache", "probe_elisions"),
    ("write_cache", "elision_rate"),
    ("write_cache", "cache_hits"),
    ("write_cache", "cache_evictions"),
    ("write_cache", "control_msgs_cache_off"),
    ("write_cache", "control_msgs_cache_on"),
    ("write_cache", "net_bytes_cache_off"),
    ("write_cache", "net_bytes_cache_on"),
    ("write_cache", "presence_fallbacks"),
    ("write_cache", "peak_dirty_bytes_cache_on"),
    ("write_cache", "wave_bytes"),
    # coalesced batch restore vs the serial read oracle on the same
    # two-batch 50%-dup workload: message / byte / elision counts and the
    # per-object fragmentation aggregates are exact functions of the
    # seeded workload and the wire model — drift means the batch planner,
    # the first-reader cache, or the read accounting changed. In
    # particular read_payload_batched is pinned to the batch's DISTINCT
    # chunk bytes (each duplicate travels once) and fetch_elisions > 0 is
    # asserted inside the bench itself. Only the *_objects_s wall-clock
    # columns are noise (not listed here).
    ("read_path", "n_objects"),
    ("read_path", "obj_kib"),
    ("read_path", "read_msgs_serial"),
    ("read_path", "read_msgs_batched"),
    ("read_path", "msg_reduction"),
    ("read_path", "read_net_bytes_serial"),
    ("read_path", "read_net_bytes_batched"),
    ("read_path", "read_payload_serial"),
    ("read_path", "read_payload_batched"),
    ("read_path", "read_batches"),
    ("read_path", "read_fallback_rounds"),
    ("read_path", "fetch_elisions"),
    ("read_path", "frag_chunks_total"),
    ("read_path", "frag_nodes_touched_total"),
    ("read_path", "frag_nodes_touched_max"),
    ("read_path", "frag_spread_max"),
    ("read_path", "modeled_time_per_edge_serial_s"),
    ("read_path", "modeled_time_per_edge_batched_s"),
    # recovery round (split-brain heal): message/byte counts and both
    # modeled-time link models are exact functions of the seeded schedule;
    # only recovery_wall_s is noise (and is not listed here)
    ("recovery", "n_objects"),
    ("recovery", "writes_failed_during_partition"),
    ("recovery", "digest_msgs"),
    ("recovery", "repair_msgs"),
    ("recovery", "audit_msgs"),
    ("recovery", "omap_repaired"),
    ("recovery", "chunks_repaired"),
    ("recovery", "cit_repaired"),
    ("recovery", "repair_bytes"),
    ("recovery", "refs_over"),
    ("recovery", "refs_under"),
    ("recovery", "flags_flipped"),
    ("recovery", "gc_removed"),
    ("recovery", "recovery_net_bytes"),
    ("recovery", "recovery_msgs"),
    ("recovery", "modeled_time_uniform_s"),
    ("recovery", "modeled_time_per_edge_s"),
    # always-on recovery: tombstone traffic and the incremental digest
    # scope (groups re-digested vs skipped) are exact functions of the
    # seeded workload — drift means the dirty-tracking or tombstone wire
    # shape changed
    ("always_on", "n_objects"),
    ("always_on", "cold_groups_digested"),
    ("always_on", "incr_groups_digested"),
    ("always_on", "incr_groups_skipped"),
    ("always_on", "incr_round_net_bytes"),
    ("always_on", "incr_round_msgs"),
    ("always_on", "tombstone_commit_msgs"),
    ("always_on", "tombstone_reap_msgs"),
    ("always_on", "tombstones_reaped"),
    ("always_on", "audit_deferred"),
    # multi-tenant scheduled workload: op outcomes, modeled tick latency
    # percentiles, interleaving witnesses (max in-flight sessions, waves
    # overlapped, superseded commits), per-edge/NIC contention maxima and
    # the seen-window sizing sweep are exact functions of the spec seed —
    # drift means the scheduler's event order, the wave pipeline, or the
    # wire shape changed. These measured margins replace the old fixed
    # 25%-of-capacity seen-window assertion. Only workload_wall_s is noise.
    ("multi_tenant", "clients"),
    ("multi_tenant", "objects"),
    ("multi_tenant", "ops_total"),
    ("multi_tenant", "puts_ok"),
    ("multi_tenant", "gets_ok"),
    ("multi_tenant", "deletes_ok"),
    ("multi_tenant", "not_found"),
    ("multi_tenant", "failures"),
    ("multi_tenant", "bytes_written"),
    ("multi_tenant", "latency_p50_ticks"),
    ("multi_tenant", "latency_p99_ticks"),
    ("multi_tenant", "elapsed_ticks"),
    ("multi_tenant", "scheduler_steps"),
    ("multi_tenant", "max_in_flight_sessions"),
    ("multi_tenant", "waves_overlapped"),
    ("multi_tenant", "writes_superseded"),
    ("multi_tenant", "probe_elisions"),
    ("multi_tenant", "cache_hits"),
    ("multi_tenant", "net_bytes"),
    ("multi_tenant", "control_msgs"),
    ("multi_tenant", "busiest_edge"),
    ("multi_tenant", "busiest_edge_payload"),
    ("multi_tenant", "node_ingress_max"),
    ("multi_tenant", "node_egress_max"),
    ("multi_tenant", "seen_window_capacity"),
    ("multi_tenant", "seen_high_water_c2"),
    ("multi_tenant", "seen_high_water_c4"),
    ("multi_tenant", "seen_high_water_c8"),
    ("multi_tenant", "seen_margin_pct_c8"),
    ("multi_tenant", "modeled_time_uniform_s"),
    ("multi_tenant", "modeled_time_per_edge_s"),
]


def compare(fresh: dict, baseline: dict) -> list[str]:
    problems: list[str] = []
    if fresh.get("quick") != baseline.get("quick"):
        problems.append(
            f"mode mismatch: fresh quick={fresh.get('quick')} vs "
            f"baseline quick={baseline.get('quick')} — gate only compares "
            f"like-for-like runs"
        )
        return problems
    for section, column in DETERMINISTIC_COLUMNS:
        f_sec, b_sec = fresh.get(section), baseline.get(section)
        if f_sec is None or b_sec is None:
            problems.append(f"missing section {section!r} "
                            f"(fresh={f_sec is not None}, baseline={b_sec is not None})")
            continue
        f_val, b_val = f_sec.get(column), b_sec.get(column)
        if f_val != b_val:
            problems.append(
                f"{section}.{column}: fresh={f_val!r} != baseline={b_val!r}"
            )
    return problems


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("fresh", type=Path, help="freshly produced BENCH_write_path.json")
    ap.add_argument("baseline", type=Path, help="committed baseline json")
    args = ap.parse_args()

    fresh = json.loads(args.fresh.read_text())
    baseline = json.loads(args.baseline.read_text())
    problems = compare(fresh, baseline)
    if problems:
        print("BENCH REGRESSION: deterministic columns drifted (tolerance 0):")
        for p in problems:
            print(f"  - {p}")
        print(
            "\nWall-clock columns are ignored by design. If this drift is an\n"
            "intentional message-shape/accounting change, regenerate the\n"
            "baseline in this PR:\n"
            f"  PYTHONPATH=src python benchmarks/write_path_bench.py --quick "
            f"--out {args.baseline}"
        )
        return 1
    checked = ", ".join(f"{s}.{c}" for s, c in DETERMINISTIC_COLUMNS)
    print(f"bench gate OK — deterministic columns match exactly ({checked})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
