"""Kernel microbenchmarks: device fingerprint path vs host SHA-256.

On this CPU container the 'device' path times the jitted jnp oracle (the
Pallas kernel is validated in interpret mode; its TPU perf is bounded by
VPU throughput — see EXPERIMENTS.md §Perf notes)."""

from __future__ import annotations

import hashlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref

MB = 1024 * 1024


def _time(fn, *args, reps=5) -> float:
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn(*args)
    if hasattr(r, "block_until_ready"):
        r.block_until_ready()
    return (time.perf_counter() - t0) / reps


def run(rows_out: list[str]) -> None:
    n_bytes = 32 * MB
    data = np.random.default_rng(0).bytes(n_bytes)

    # host path: SHA-256 over 512 KB chunks
    def host_fp():
        return [hashlib.sha256(data[o:o + 512 * 1024]).digest()
                for o in range(0, n_bytes, 512 * 1024)]

    t_host = _time(host_fp)
    rows_out.append(f"kernel_host_sha256_32MB,{t_host*1e6:.0f},MBps={n_bytes/t_host/1e6:.0f}")

    # device path: vectorized fingerprint (jnp oracle, jitted)
    words = jnp.asarray(np.frombuffer(data, np.uint32)).reshape(64, -1)
    fp_jit = jax.jit(ref.fingerprint_chunks)
    t_dev = _time(fp_jit, words)
    rows_out.append(f"kernel_device_fp_32MB,{t_dev*1e6:.0f},MBps={n_bytes/t_dev/1e6:.0f}")

    # CDC window hashes
    tvals = jnp.asarray(np.frombuffer(data[: 4 * MB], np.uint8).astype(np.uint32))
    cdc_jit = jax.jit(ref.cdc_hashes)
    t_cdc = _time(cdc_jit, tvals)
    rows_out.append(f"kernel_cdc_hash_4MB,{t_cdc*1e6:.0f},MBps={4*MB/t_cdc/1e6:.0f}")
