"""Render the §Dry-run / §Roofline tables from results/dryrun/*.json.

    PYTHONPATH=src python -m benchmarks.roofline [--mesh pod16x16] [--md]
"""

from __future__ import annotations

import argparse
import json
import pathlib

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load(mesh: str, tag: str = "") -> list[dict]:
    recs = []
    for f in sorted(RESULTS.glob(f"*__{mesh}{('__' + tag) if tag else ''}.json")):
        r = json.loads(f.read_text())
        if tag == "" and r.get("tag"):
            continue
        recs.append(r)
    return recs


def fmt_table(recs: list[dict], md: bool = False) -> str:
    hdr = ["arch", "shape", "status", "chips", "params",
           "t_comp_ms", "t_mem_ms", "t_coll_ms", "bound",
           "useful", "roofline", "peakGB/dev"]
    rows = [hdr]
    for r in recs:
        if r["status"] != "ok":
            rows.append([r["arch"], r["shape"], r["status"], "-", "-", "-", "-", "-",
                         r.get("reason", r.get("error", ""))[:40], "-", "-", "-"])
            continue
        ro = r["roofline"]
        rows.append([
            r["arch"], r["shape"], "ok", str(r["n_chips"]),
            f"{r['n_params']/1e9:.2f}B",
            f"{ro['t_compute_s']*1e3:.2f}",
            f"{ro['t_memory_s']*1e3:.2f}",
            f"{ro['t_collective_s']*1e3:.2f}",
            ro["bottleneck"],
            f"{ro['useful_flops_ratio']:.2f}",
            f"{ro['roofline_fraction']:.3f}",
            f"{(r['memory']['peak_bytes'] or 0)/1e9:.2f}",
        ])
    if md:
        out = ["| " + " | ".join(rows[0]) + " |",
               "|" + "---|" * len(rows[0])]
        out += ["| " + " | ".join(r) + " |" for r in rows[1:]]
        return "\n".join(out)
    w = [max(len(r[i]) for r in rows) for i in range(len(hdr))]
    return "\n".join("  ".join(c.ljust(w[i]) for i, c in enumerate(r)) for r in rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--tag", default="")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    recs = load(args.mesh, args.tag)
    print(fmt_table(recs, md=args.md))
    okc = sum(r["status"] == "ok" for r in recs)
    sk = sum(r["status"] == "skipped" for r in recs)
    fl = sum(r["status"] == "fail" for r in recs)
    print(f"\n{args.mesh}: ok={okc} skipped={sk} failed={fl}")


if __name__ == "__main__":
    main()
